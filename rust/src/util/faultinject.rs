//! Deterministic fault injection for the serving pool.
//!
//! The fault-tolerance layer (panic isolation, reply guards, shard
//! supervision — `coordinator::pool`) is only trustworthy if it can be
//! exercised under *reproducible* faults.  This module provides the
//! seeded fault source: a [`FaultSpec`] parsed from the CLI
//! (`repro serve --fault-spec panic=0.02,error=0.01`), and a
//! [`FaultPlan`] that draws per-request fault decisions from the same
//! in-tree [`Mt19937`] the open-loop load generator uses — equal specs
//! yield byte-identical fault sequences, so a chaos run that found a
//! bug replays exactly.
//!
//! Fault kinds, drawn independently per engine pass:
//!
//! - **error** — the engine returns `Err` (an admitted request fails
//!   cleanly; the pool converts it to an error reply).
//! - **panic** — the engine panics mid-pass.  The pool's
//!   `catch_unwind` isolation must convert this into error replies for
//!   the whole batch and keep the worker alive.
//! - **fatal** — the engine panics with the [`FatalFault`] marker
//!   payload, which the pool deliberately re-raises *after* resolving
//!   replies: the worker thread dies and shard supervision must
//!   respawn it.  This is how worker death is made reproducible.
//! - **delay** — the pass sleeps [`FaultSpec::delay_us`] first (a
//!   latency spike; exercises deadlines and the SLO loop).
//! - **drop** — net-level only: the server severs the connection
//!   instead of replying (exercises reader-thread cleanup and client
//!   retry bounds).  Drawn from a separate [`FaultPlan`] by the
//!   `coordinator::net` front end, never by engines.
//!
//! The injection wrapper itself ([`FaultyInstance`]) lives in
//! `coordinator::instance` next to the other `EqualizerInstance`
//! flavors; this module is the spec + the deterministic draw.

use crate::channel::mt19937::Mt19937;
use anyhow::Result;
use std::time::Duration;

/// Fault rates and the seed that makes them reproducible.  Parsed from
/// a `key=value` comma list (see [`FaultSpec::from_str`]); all rates
/// are per engine pass (or per frame, for `drop`) in `[0, 1]`.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultSpec {
    /// Probability an engine pass panics (caught by the pool).
    pub panic: f64,
    /// Probability an engine pass panics with [`FatalFault`] (kills
    /// the worker thread; supervision must respawn it).
    pub fatal: f64,
    /// Probability an engine pass returns an error.
    pub error: f64,
    /// Probability an engine pass is delayed by [`Self::delay_us`].
    pub delay: f64,
    /// Latency-spike size for `delay` faults, microseconds.
    pub delay_us: u64,
    /// Probability the net front end drops a connection instead of
    /// replying to a frame.
    pub drop: f64,
    /// Seed for the per-instance [`FaultPlan`]s; equal specs yield
    /// identical fault sequences.
    pub seed: u32,
}

impl Default for FaultSpec {
    fn default() -> Self {
        Self {
            panic: 0.0,
            fatal: 0.0,
            error: 0.0,
            delay: 0.0,
            delay_us: 500,
            drop: 0.0,
            seed: 0xfa_17,
        }
    }
}

impl FaultSpec {
    /// Check the spec is injectable: every rate in `[0, 1]`, and the
    /// engine-fault rates must not sum past 1 (they partition one
    /// uniform draw).
    pub fn validate(&self) -> Result<()> {
        for (name, rate) in [
            ("panic", self.panic),
            ("fatal", self.fatal),
            ("error", self.error),
            ("delay", self.delay),
            ("drop", self.drop),
        ] {
            anyhow::ensure!(
                rate.is_finite() && (0.0..=1.0).contains(&rate),
                "fault rate {name} must be in [0, 1], got {rate}"
            );
        }
        let sum = self.panic + self.fatal + self.error + self.delay;
        anyhow::ensure!(
            sum <= 1.0,
            "engine fault rates sum to {sum}, but they partition one draw (must be <= 1)"
        );
        anyhow::ensure!(self.delay == 0.0 || self.delay_us > 0, "delay faults need delay-us > 0");
        Ok(())
    }

    /// True if any engine-level fault can fire (the pool skips the
    /// wrapper entirely otherwise).
    pub fn any_engine_fault(&self) -> bool {
        self.panic > 0.0 || self.fatal > 0.0 || self.error > 0.0 || self.delay > 0.0
    }

    /// A plan for one injection site.  `stream` decorrelates sites
    /// (e.g. one per engine instance, one per net connection) while
    /// keeping the whole run a pure function of the spec.
    pub fn plan(&self, stream: u32) -> FaultPlan {
        FaultPlan::new(self, self.seed.wrapping_add(stream.wrapping_mul(0x9e37_79b9)))
    }
}

impl std::str::FromStr for FaultSpec {
    type Err = anyhow::Error;

    /// Parse `"panic=0.02,error=0.01,delay=0.05,delay-us=500,drop=0.01,seed=7"`.
    /// Unset keys keep their [`FaultSpec::default`] values.
    fn from_str(s: &str) -> Result<Self> {
        let mut spec = FaultSpec::default();
        for part in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (key, value) = part
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("fault spec part {part:?} is not key=value"))?;
            let bad = |e| anyhow::anyhow!("fault spec {key}={value}: {e}");
            match key {
                "panic" => spec.panic = value.parse().map_err(bad)?,
                "fatal" => spec.fatal = value.parse().map_err(bad)?,
                "error" => spec.error = value.parse().map_err(bad)?,
                "delay" => spec.delay = value.parse().map_err(bad)?,
                "delay-us" | "delay_us" => spec.delay_us = value.parse().map_err(bad)?,
                "drop" => spec.drop = value.parse().map_err(bad)?,
                "seed" => spec.seed = value.parse().map_err(bad)?,
                other => anyhow::bail!(
                    "unknown fault spec key {other:?} \
                     (panic|fatal|error|delay|delay-us|drop|seed)"
                ),
            }
        }
        spec.validate()?;
        Ok(spec)
    }
}

/// One fault decision for an engine pass.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Fault {
    /// Panic mid-pass (recoverable: the pool catches it).
    Panic,
    /// Panic with the [`FatalFault`] payload (kills the worker).
    Fatal,
    /// Return an engine error.
    Error,
    /// Sleep this long, then serve normally.
    Delay(Duration),
}

/// Panic payload that marks a fault as *worker-fatal*: the pool's
/// `catch_unwind` isolation resolves the batch's replies, then
/// re-raises this payload so the worker thread actually dies and the
/// supervisor's respawn path is exercised.  Nothing outside fault
/// injection ever panics with this type.
#[derive(Debug)]
pub struct FatalFault;

/// A seeded stream of fault decisions — the deterministic core.  One
/// uniform draw per call; the engine fault rates partition `[0, 1)` in
/// the fixed order panic | fatal | error | delay, so the sequence of
/// decisions is byte-identical for equal `(spec, stream)` pairs.
#[derive(Debug)]
pub struct FaultPlan {
    rng: Mt19937,
    panic: f64,
    fatal: f64,
    error: f64,
    delay: f64,
    delay_us: u64,
    drop: f64,
}

impl FaultPlan {
    fn new(spec: &FaultSpec, seed: u32) -> Self {
        Self {
            rng: Mt19937::new(seed),
            panic: spec.panic,
            fatal: spec.fatal,
            error: spec.error,
            delay: spec.delay,
            delay_us: spec.delay_us,
            drop: spec.drop,
        }
    }

    /// Draw the fault decision for the next engine pass.
    pub fn draw(&mut self) -> Option<Fault> {
        let u = self.rng.next_f64();
        let mut edge = self.panic;
        if u < edge {
            return Some(Fault::Panic);
        }
        edge += self.fatal;
        if u < edge {
            return Some(Fault::Fatal);
        }
        edge += self.error;
        if u < edge {
            return Some(Fault::Error);
        }
        edge += self.delay;
        if u < edge {
            return Some(Fault::Delay(Duration::from_micros(self.delay_us)));
        }
        None
    }

    /// Draw the drop decision for the next net frame (independent of
    /// the engine-fault partition; net plans use a different stream).
    pub fn draw_drop(&mut self) -> bool {
        self.drop > 0.0 && self.rng.next_f64() < self.drop
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_parses_the_full_key_set_and_defaults_the_rest() {
        let spec: FaultSpec =
            "panic=0.02,error=0.01,delay=0.05,delay-us=250,drop=0.1,seed=7".parse().unwrap();
        assert_eq!(spec.panic, 0.02);
        assert_eq!(spec.error, 0.01);
        assert_eq!(spec.delay, 0.05);
        assert_eq!(spec.delay_us, 250);
        assert_eq!(spec.drop, 0.1);
        assert_eq!(spec.seed, 7);
        assert_eq!(spec.fatal, 0.0, "unset keys keep defaults");
        let spec: FaultSpec = "fatal=0.005".parse().unwrap();
        assert_eq!(spec.fatal, 0.005);
        assert_eq!(spec.delay_us, FaultSpec::default().delay_us);
        assert!(spec.any_engine_fault());
        assert!(!FaultSpec::default().any_engine_fault());
    }

    #[test]
    fn spec_rejects_malformed_and_out_of_range_input() {
        assert!("panic".parse::<FaultSpec>().is_err(), "not key=value");
        assert!("panic=1.5".parse::<FaultSpec>().is_err(), "rate above 1");
        assert!("error=-0.1".parse::<FaultSpec>().is_err(), "negative rate");
        assert!("jitter=0.1".parse::<FaultSpec>().is_err(), "unknown key");
        assert!("panic=nope".parse::<FaultSpec>().is_err(), "unparsable value");
        assert!(
            "panic=0.6,error=0.6".parse::<FaultSpec>().is_err(),
            "engine rates must partition one draw"
        );
        assert!("delay=0.1,delay-us=0".parse::<FaultSpec>().is_err(), "zero-length delay");
        assert!("".parse::<FaultSpec>().is_ok(), "empty spec = no faults");
    }

    #[test]
    fn plans_are_deterministic_per_spec_and_stream() {
        let spec: FaultSpec = "panic=0.1,error=0.2,delay=0.1".parse().unwrap();
        let draws = |spec: &FaultSpec, stream| {
            let mut plan = spec.plan(stream);
            (0..500).map(|_| plan.draw()).collect::<Vec<_>>()
        };
        assert_eq!(draws(&spec, 0), draws(&spec, 0), "equal (spec, stream) => equal draws");
        assert_ne!(draws(&spec, 0), draws(&spec, 1), "streams decorrelate");
        let mut reseeded = spec.clone();
        reseeded.seed ^= 1;
        assert_ne!(draws(&spec, 0), draws(&reseeded, 0), "the seed matters");
    }

    #[test]
    fn draw_rates_approach_the_spec() {
        let spec: FaultSpec = "panic=0.1,fatal=0.05,error=0.2,delay=0.1".parse().unwrap();
        let mut plan = spec.plan(3);
        let n = 20_000;
        let (mut p, mut f, mut e, mut d, mut none) = (0, 0, 0, 0, 0);
        for _ in 0..n {
            match plan.draw() {
                Some(Fault::Panic) => p += 1,
                Some(Fault::Fatal) => f += 1,
                Some(Fault::Error) => e += 1,
                Some(Fault::Delay(dur)) => {
                    assert_eq!(dur, Duration::from_micros(spec.delay_us));
                    d += 1;
                }
                None => none += 1,
            }
        }
        let frac = |k: i64| k as f64 / n as f64;
        assert!((frac(p) - 0.10).abs() < 0.02, "panic rate {}", frac(p));
        assert!((frac(f) - 0.05).abs() < 0.02, "fatal rate {}", frac(f));
        assert!((frac(e) - 0.20).abs() < 0.02, "error rate {}", frac(e));
        assert!((frac(d) - 0.10).abs() < 0.02, "delay rate {}", frac(d));
        assert!((frac(none) - 0.55).abs() < 0.03, "clean rate {}", frac(none));
    }

    #[test]
    fn drop_draws_are_independent_and_deterministic() {
        let spec: FaultSpec = "drop=0.3".parse().unwrap();
        let mut a = spec.plan(9);
        let mut b = spec.plan(9);
        let hits: Vec<bool> = (0..200).map(|_| a.draw_drop()).collect();
        assert_eq!(hits, (0..200).map(|_| b.draw_drop()).collect::<Vec<_>>());
        let rate = hits.iter().filter(|h| **h).count() as f64 / 200.0;
        assert!((rate - 0.3).abs() < 0.12, "drop rate {rate}");
        let mut none = FaultSpec::default().plan(0);
        assert!((0..100).all(|_| !none.draw_drop()), "zero rate never drops");
        assert!((0..100).all(|_| none.draw().is_none()), "empty spec never faults");
    }
}
