//! Property-testing helpers (proptest is not vendored offline).
//!
//! A deterministic case generator over the in-tree MT19937: each
//! property runs `cases` times with derived seeds; failures report the
//! seed so they replay exactly.

use crate::channel::mt19937::Mt19937;

/// Random-input generator for one property case.
pub struct Gen {
    rng: Mt19937,
    pub seed: u32,
}

impl Gen {
    pub fn new(seed: u32) -> Self {
        Self { rng: Mt19937::new(seed), seed }
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + (self.rng.next_u32() as usize) % (hi - lo + 1)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (self.rng.next_f64() as f32) * (hi - lo)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u32() & 1 == 1
    }

    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32_in(lo, hi)).collect()
    }

    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.usize_in(0, items.len() - 1)]
    }
}

/// Run `prop` for `cases` derived seeds; panics with the failing seed.
pub fn check(cases: u32, prop: impl Fn(&mut Gen)) {
    for i in 0..cases {
        let seed = 0xC0FFEE ^ i.wrapping_mul(2_654_435_761);
        let mut g = Gen::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut g)));
        if let Err(e) = result {
            eprintln!("property failed on case {i} (seed {seed:#x})");
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ranges_respected() {
        check(50, |g| {
            let n = g.usize_in(3, 17);
            assert!((3..=17).contains(&n));
            let x = g.f32_in(-1.0, 1.0);
            assert!((-1.0..=1.0).contains(&x));
            let v = g.vec_f32(n, 0.0, 2.0);
            assert_eq!(v.len(), n);
            assert!(v.iter().all(|&f| (0.0..=2.0).contains(&f)));
        });
    }

    #[test]
    fn deterministic_per_seed() {
        let mut a = Gen::new(9);
        let mut b = Gen::new(9);
        for _ in 0..20 {
            assert_eq!(a.usize_in(0, 1000), b.usize_in(0, 1000));
        }
    }

    #[test]
    #[should_panic]
    fn failures_propagate() {
        check(10, |g| {
            assert!(g.usize_in(0, 4) > 4, "always fails");
        });
    }
}
