//! In-tree utility substrates.
//!
//! This image builds fully offline with only the `xla` crate closure
//! vendored, so the usual ecosystem crates (serde_json, clap, criterion,
//! proptest, tokio) are unavailable — these modules provide the subset
//! this project needs, with their own test suites.

pub mod bench;
pub mod cli;
pub mod faultinject;
pub mod json;
pub mod loadgen;
pub mod prop;
