//! Minimal JSON parser + writer (serde_json is not vendored offline).
//!
//! Covers the full JSON grammar (RFC 8259) minus exotic number forms;
//! enough for `manifest.json`, `weights_*.json`, `dse_*.json` and the
//! config files.  Strict: trailing garbage, unterminated strings and
//! malformed escapes are errors.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- accessors -------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["key"]` with a useful error.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Flatten an arbitrarily nested numeric array, returning the values
    /// and the dimensions (row-major).  Ragged arrays are errors.
    pub fn as_tensor_f32(&self) -> Result<(Vec<f32>, Vec<usize>)> {
        fn walk(v: &Json, depth: usize, dims: &mut Vec<usize>, out: &mut Vec<f32>) -> Result<()> {
            match v {
                Json::Num(n) => {
                    if dims.len() != depth {
                        bail!("ragged array: scalar at depth {depth}");
                    }
                    out.push(*n as f32);
                    Ok(())
                }
                Json::Arr(items) => {
                    if dims.len() == depth {
                        dims.push(items.len());
                    } else if dims[depth] != items.len() {
                        bail!("ragged array at depth {depth}");
                    }
                    for it in items {
                        walk(it, depth + 1, dims, out)?;
                    }
                    Ok(())
                }
                other => bail!("non-numeric element: {other:?}"),
            }
        }
        let mut dims = Vec::new();
        let mut out = Vec::new();
        walk(self, 0, &mut dims, &mut out)?;
        Ok((out, dims))
    }

    // ---- writer ----------------------------------------------------------

    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Parse a complete JSON document.
pub fn parse(text: &str) -> Result<Json> {
    let bytes = text.as_bytes();
    let mut pos = 0usize;
    let v = parse_value(bytes, &mut pos)?;
    skip_ws(bytes, &mut pos);
    if pos != bytes.len() {
        bail!("trailing garbage at byte {pos}");
    }
    Ok(v)
}

/// Parse a JSON file.
pub fn parse_file(path: impl AsRef<std::path::Path>) -> Result<Json> {
    let path = path.as_ref();
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow!("reading {}: {e}", path.display()))?;
    parse(&text).map_err(|e| anyhow!("parsing {}: {e}", path.display()))
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json> {
    skip_ws(b, pos);
    let Some(&c) = b.get(*pos) else { bail!("unexpected end of input") };
    match c {
        b'{' => parse_obj(b, pos),
        b'[' => parse_arr(b, pos),
        b'"' => Ok(Json::Str(parse_string(b, pos)?)),
        b't' => lit(b, pos, "true", Json::Bool(true)),
        b'f' => lit(b, pos, "false", Json::Bool(false)),
        b'n' => lit(b, pos, "null", Json::Null),
        b'-' | b'0'..=b'9' => parse_num(b, pos),
        other => bail!("unexpected byte {:?} at {}", other as char, *pos),
    }
}

fn lit(b: &[u8], pos: &mut usize, word: &str, v: Json) -> Result<Json> {
    if b[*pos..].starts_with(word.as_bytes()) {
        *pos += word.len();
        Ok(v)
    } else {
        bail!("invalid literal at byte {}", *pos)
    }
}

fn parse_num(b: &[u8], pos: &mut usize) -> Result<Json> {
    let start = *pos;
    if b.get(*pos) == Some(&b'-') {
        *pos += 1;
    }
    while *pos < b.len() && matches!(b[*pos], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-') {
        *pos += 1;
    }
    let s = std::str::from_utf8(&b[start..*pos])?;
    Ok(Json::Num(s.parse::<f64>().map_err(|e| anyhow!("bad number {s:?}: {e}"))?))
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String> {
    debug_assert_eq!(b[*pos], b'"');
    *pos += 1;
    let mut out = String::new();
    loop {
        let Some(&c) = b.get(*pos) else { bail!("unterminated string") };
        *pos += 1;
        match c {
            b'"' => return Ok(out),
            b'\\' => {
                let Some(&e) = b.get(*pos) else { bail!("bad escape") };
                *pos += 1;
                match e {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'n' => out.push('\n'),
                    b't' => out.push('\t'),
                    b'r' => out.push('\r'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'u' => {
                        if *pos + 4 > b.len() {
                            bail!("bad \\u escape");
                        }
                        let hex = std::str::from_utf8(&b[*pos..*pos + 4])?;
                        let cp = u32::from_str_radix(hex, 16)?;
                        *pos += 4;
                        out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                    }
                    other => bail!("unknown escape \\{}", other as char),
                }
            }
            c => {
                // Re-assemble multibyte UTF-8 sequences.
                if c < 0x80 {
                    out.push(c as char);
                } else {
                    let len = utf8_len(c);
                    let end = *pos - 1 + len;
                    if end > b.len() {
                        bail!("truncated UTF-8");
                    }
                    out.push_str(std::str::from_utf8(&b[*pos - 1..end])?);
                    *pos = end;
                }
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

fn parse_arr(b: &[u8], pos: &mut usize) -> Result<Json> {
    *pos += 1; // consume '['
    let mut items = Vec::new();
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b']') {
        *pos += 1;
        return Ok(Json::Arr(items));
    }
    loop {
        items.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(&b',') => *pos += 1,
            Some(&b']') => {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            _ => bail!("expected ',' or ']' at byte {}", *pos),
        }
    }
}

fn parse_obj(b: &[u8], pos: &mut usize) -> Result<Json> {
    *pos += 1; // consume '{'
    let mut map = BTreeMap::new();
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b'}') {
        *pos += 1;
        return Ok(Json::Obj(map));
    }
    loop {
        skip_ws(b, pos);
        if b.get(*pos) != Some(&b'"') {
            bail!("expected string key at byte {}", *pos);
        }
        let key = parse_string(b, pos)?;
        skip_ws(b, pos);
        if b.get(*pos) != Some(&b':') {
            bail!("expected ':' at byte {}", *pos);
        }
        *pos += 1;
        map.insert(key, parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(&b',') => *pos += 1,
            Some(&b'}') => {
                *pos += 1;
                return Ok(Json::Obj(map));
            }
            _ => bail!("expected ',' or '}}' at byte {}", *pos),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn nested_structure() {
        let v = parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn tensor_flatten() {
        let v = parse("[[1, 2, 3], [4, 5, 6]]").unwrap();
        let (data, dims) = v.as_tensor_f32().unwrap();
        assert_eq!(dims, vec![2, 3]);
        assert_eq!(data, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn ragged_tensor_rejected() {
        let v = parse("[[1, 2], [3]]").unwrap();
        assert!(v.as_tensor_f32().is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"a":[1,2.5,"x"],"b":{"c":null,"d":true}}"#;
        let v = parse(src).unwrap();
        assert_eq!(parse(&v.render()).unwrap(), v);
    }

    #[test]
    fn errors() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("42 garbage").is_err());
        assert!(parse("{'a': 1}").is_err());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(parse(r#""é""#).unwrap(), Json::Str("é".into()));
        assert_eq!(parse("\"héllo\"").unwrap(), Json::Str("héllo".into()));
    }

    #[test]
    fn whitespace_tolerant() {
        let v = parse(" {\n\t\"k\" : [ 1 , 2 ] } ").unwrap();
        assert_eq!(v.get("k").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn usize_accessor() {
        assert_eq!(parse("7").unwrap().as_usize(), Some(7));
        assert_eq!(parse("7.5").unwrap().as_usize(), None);
        assert_eq!(parse("-1").unwrap().as_usize(), None);
    }
}
