//! Tiny argument parser (clap is not vendored offline).
//!
//! Supports `--flag value`, `--flag=value` and positional arguments —
//! all the CLI and examples need.

use anyhow::{anyhow, Result};
use std::collections::HashMap;

/// Parsed command line: positionals + `--key value` options.
#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    opts: HashMap<String, String>,
}

impl Args {
    /// Parse `std::env::args()` (skipping argv[0]).
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn parse(iter: impl IntoIterator<Item = String>) -> Self {
        let mut out = Args::default();
        let mut iter = iter.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if iter.peek().is_some_and(|n| !n.starts_with("--")) {
                    out.opts.insert(rest.to_string(), iter.next().unwrap());
                } else {
                    out.opts.insert(rest.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow!("--{key}: {e}")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow!("--{key}: {e}")),
        }
    }

    pub fn flag(&self, key: &str) -> bool {
        self.get(key).is_some_and(|v| v != "false")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn positional_and_options() {
        let a = parse("figures fig2 --artifacts art --n 5");
        assert_eq!(a.positional, vec!["figures", "fig2"]);
        assert_eq!(a.get("artifacts"), Some("art"));
        assert_eq!(a.usize_or("n", 0).unwrap(), 5);
    }

    #[test]
    fn equals_form_and_flags() {
        // Boolean flags bind a following bare token as their value, so
        // they go last (or use --flag=true) — documented limitation.
        let a = parse("run --x=3.5 --verbose");
        assert_eq!(a.f64_or("x", 0.0).unwrap(), 3.5);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        assert_eq!(a.positional, vec!["run"]);
    }

    #[test]
    fn defaults() {
        let a = parse("cmd");
        assert_eq!(a.usize_or("n", 7).unwrap(), 7);
        assert_eq!(a.str_or("s", "d"), "d");
    }

    #[test]
    fn bad_number_is_error() {
        let a = parse("--n abc");
        assert!(a.usize_or("n", 0).is_err());
    }
}
