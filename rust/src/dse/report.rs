//! Load `artifacts/dse_*.json` (the Python sweep output) and render the
//! Fig. 2 / Fig. 4 tables.

use super::pareto::{pareto_front, select, DsePoint};
use crate::hw::device::Device;
use crate::hw::resource::mac_sym_max;
use crate::util::json::{self, Json};
use anyhow::{anyhow, Result};
use std::path::Path;

/// Parsed DSE sweep file.
#[derive(Debug)]
pub struct DseFile {
    pub channel: String,
    pub iters: u64,
    pub seeds: u64,
    pub results: Vec<DseEntry>,
}

/// One trained configuration row.
#[derive(Debug)]
pub struct DseEntry {
    pub family: String,
    pub config: String,
    pub mac_per_symbol: f64,
    pub ber: f64,
}

impl DseFile {
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let root = json::parse_file(path.as_ref())?;
        let results = root
            .req("results")?
            .as_arr()
            .ok_or_else(|| anyhow!("results must be an array"))?
            .iter()
            .map(|e| {
                Ok(DseEntry {
                    family: e.req("family")?.as_str().ok_or_else(|| anyhow!("family"))?.into(),
                    config: e.req("config")?.render(),
                    mac_per_symbol: e
                        .req("mac_per_symbol")?
                        .as_f64()
                        .ok_or_else(|| anyhow!("mac_per_symbol"))?,
                    ber: e.req("ber")?.as_f64().ok_or_else(|| anyhow!("ber"))?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Self {
            channel: root.req("channel")?.as_str().unwrap_or("?").into(),
            iters: root.get("iters").and_then(Json::as_f64).unwrap_or(0.0) as u64,
            seeds: root.get("seeds").and_then(Json::as_f64).unwrap_or(0.0) as u64,
            results,
        })
    }

    pub fn points(&self, family: &str) -> Vec<DsePoint> {
        self.results
            .iter()
            .filter(|e| e.family == family)
            .map(|e| DsePoint {
                family: e.family.clone(),
                label: e.config.clone(),
                mac_per_symbol: e.mac_per_symbol,
                ber: e.ber.max(1e-7), // log-axis floor: 0 errors observed
            })
            .collect()
    }
}

/// The Fig. 2 / Fig. 4 report: per-family Pareto fronts plus the
/// hardware-constrained selection.
pub struct FigureReport {
    pub channel: String,
    pub fronts: Vec<(String, Vec<DsePoint>)>,
    pub ceiling: f64,
    pub selected: Option<DsePoint>,
}

impl FigureReport {
    pub fn build(file: &DseFile, dev: &Device, t_req_baud: f64) -> Self {
        let ceiling = mac_sym_max(dev, t_req_baud);
        let mut fronts = Vec::new();
        for family in ["cnn", "fir", "volterra"] {
            let pts = file.points(family);
            if !pts.is_empty() {
                fronts.push((family.to_string(), pareto_front(&pts)));
            }
        }
        let selected = select(&file.points("cnn"), ceiling);
        Self { channel: file.channel.clone(), fronts, ceiling, selected }
    }

    /// Text rendering (the "rows/series the paper reports").
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "channel={}  MAC ceiling (DSP*f_clk*1.2/T_req) = {:.1}\n",
            self.channel, self.ceiling
        ));
        for (family, front) in &self.fronts {
            out.push_str(&format!("-- {family} Pareto front --\n"));
            for p in front {
                out.push_str(&format!(
                    "  mac/sym {:8.1}  BER {:9.3e}  {}\n",
                    p.mac_per_symbol, p.ber, p.label
                ));
            }
        }
        if let Some(sel) = &self.selected {
            out.push_str(&format!(
                "SELECTED (lowest BER under ceiling): mac/sym {:.1} BER {:.3e} {}\n",
                sel.mac_per_symbol, sel.ber, sel.label
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::device::XCVU13P;

    fn sample_file() -> DseFile {
        let text = r#"{
          "channel": "imdd", "iters": 100, "seeds": 1, "full": false,
          "results": [
            {"family": "cnn", "config": {"vp": 8}, "mac_per_symbol": 56.25, "ber": 1e-3},
            {"family": "cnn", "config": {"vp": 4}, "mac_per_symbol": 120.0, "ber": 5e-4},
            {"family": "fir", "config": {"taps": 57}, "mac_per_symbol": 57.0, "ber": 4e-3},
            {"family": "volterra", "config": {"m1": 25}, "mac_per_symbol": 61.0, "ber": 8e-3}
          ]
        }"#;
        let tmp = std::env::temp_dir().join("dse_test_sample.json");
        std::fs::write(&tmp, text).unwrap();
        DseFile::load(&tmp).unwrap()
    }

    #[test]
    fn parse_and_report() {
        let f = sample_file();
        assert_eq!(f.results.len(), 4);
        let rep = FigureReport::build(&f, &XCVU13P, 40e9);
        assert_eq!(rep.fronts.len(), 3);
        let sel = rep.selected.as_ref().unwrap();
        assert_eq!(sel.mac_per_symbol, 56.25); // 120 exceeds the 73.7 ceiling
        let text = rep.render();
        assert!(text.contains("SELECTED"));
        assert!(text.contains("cnn Pareto front"));
    }

    #[test]
    fn ber_floor_applied() {
        let text = r#"{"channel":"x","results":[
            {"family":"cnn","config":{},"mac_per_symbol":1,"ber":0}]}"#;
        let tmp = std::env::temp_dir().join("dse_test_floor.json");
        std::fs::write(&tmp, text).unwrap();
        let f = DseFile::load(&tmp).unwrap();
        assert!(f.points("cnn")[0].ber > 0.0);
    }
}
