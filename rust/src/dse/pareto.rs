//! Pareto-front extraction over (complexity, BER) points.

/// One trained configuration from the DSE sweep.
#[derive(Debug, Clone, PartialEq)]
pub struct DsePoint {
    pub family: String,
    pub label: String,
    pub mac_per_symbol: f64,
    pub ber: f64,
}

/// Points not dominated by any other: no other point has both lower (or
/// equal) complexity *and* lower (or equal) BER with one strict.
/// Returned sorted by complexity ascending — the dotted/solid/dashed
/// front lines of Fig. 2.
pub fn pareto_front(points: &[DsePoint]) -> Vec<DsePoint> {
    let mut sorted: Vec<&DsePoint> = points.iter().collect();
    sorted.sort_by(|a, b| {
        a.mac_per_symbol
            .partial_cmp(&b.mac_per_symbol)
            .unwrap()
            .then(a.ber.partial_cmp(&b.ber).unwrap())
    });
    let mut front: Vec<DsePoint> = Vec::new();
    let mut best_ber = f64::INFINITY;
    for p in sorted {
        if p.ber < best_ber {
            best_ber = p.ber;
            front.push(p.clone());
        }
    }
    front
}

/// The configuration the paper selects: lowest BER among points whose
/// complexity satisfies the hardware ceiling (Sec. 3.5).
pub fn select(points: &[DsePoint], mac_ceiling: f64) -> Option<DsePoint> {
    points
        .iter()
        .filter(|p| p.mac_per_symbol <= mac_ceiling)
        .min_by(|a, b| a.ber.partial_cmp(&b.ber).unwrap())
        .cloned()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pt(mac: f64, ber: f64) -> DsePoint {
        DsePoint { family: "cnn".into(), label: format!("{mac}/{ber}"), mac_per_symbol: mac, ber }
    }

    #[test]
    fn dominated_points_removed() {
        let pts = vec![pt(10.0, 1e-2), pt(20.0, 1e-3), pt(15.0, 5e-2), pt(30.0, 1e-4)];
        let front = pareto_front(&pts);
        let labels: Vec<f64> = front.iter().map(|p| p.mac_per_symbol).collect();
        assert_eq!(labels, vec![10.0, 20.0, 30.0]); // 15.0 dominated by 10.0
    }

    #[test]
    fn front_is_monotone() {
        let pts: Vec<DsePoint> = (0..50)
            .map(|i| pt((i % 10) as f64 * 7.0 + 3.0, 1e-2 / ((i + 1) as f64)))
            .collect();
        let front = pareto_front(&pts);
        for w in front.windows(2) {
            assert!(w[1].mac_per_symbol > w[0].mac_per_symbol);
            assert!(w[1].ber < w[0].ber);
        }
    }

    #[test]
    fn select_respects_ceiling() {
        let pts = vec![pt(10.0, 1e-2), pt(50.0, 1e-3), pt(500.0, 1e-5)];
        let sel = select(&pts, 100.0).unwrap();
        assert_eq!(sel.mac_per_symbol, 50.0);
    }

    #[test]
    fn select_none_when_all_too_big() {
        let pts = vec![pt(500.0, 1e-5)];
        assert!(select(&pts, 100.0).is_none());
    }

    #[test]
    fn single_point_is_front() {
        let pts = vec![pt(1.0, 0.1)];
        assert_eq!(pareto_front(&pts), pts);
    }
}
