//! Design-space-exploration result processing (Figs. 2 and 4).
//!
//! The training sweep itself runs at build time (`python -m compile.dse`
//! writes `artifacts/dse_*.json`); this module loads those results,
//! computes Pareto fronts, applies the hardware-aware complexity
//! ceiling (Sec. 3.4) and renders the figure tables.

pub mod pareto;
pub mod report;
