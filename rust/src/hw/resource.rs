//! FPGA resource model (Table 1, Fig. 8a).
//!
//! Analytic stand-in for Vivado place-and-route (DESIGN.md §3): counts
//! MAC engines, pipeline registers, stream-module buffers and control
//! per instance and per SSM/MSM, with constants calibrated so the
//! 64-instance XCVU13P design reproduces the paper's Table 1 and the
//! XC7S25 DOP sweep reproduces the Fig. 8a shape (DSPs exhausted at
//! DOP 225 -> MAC overflow into LUTs; parameters move from BRAM into
//! LUTs at high DOP).

use super::device::Device;
use super::dop::Dop;
use crate::equalizer::weights::CnnTopologyCfg;

/// Resource usage of one design point.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ResourceUsage {
    pub luts: u64,
    pub ffs: u64,
    pub dsps: u64,
    pub brams: u64,
}

impl ResourceUsage {
    pub fn utilization(&self, dev: &Device) -> ResourceUtilization {
        ResourceUtilization {
            lut_pct: 100.0 * self.luts as f64 / dev.luts as f64,
            ff_pct: 100.0 * self.ffs as f64 / dev.ffs as f64,
            dsp_pct: 100.0 * self.dsps as f64 / dev.dsps as f64,
            bram_pct: 100.0 * self.brams as f64 / dev.brams as f64,
        }
    }

    pub fn fits(&self, dev: &Device) -> bool {
        self.luts <= dev.luts
            && self.ffs <= dev.ffs
            && self.dsps <= dev.dsps
            && self.brams <= dev.brams
    }
}

/// Percent-of-device view (the paper's Table 1 / Fig. 8a axis).
#[derive(Debug, Clone, Copy)]
pub struct ResourceUtilization {
    pub lut_pct: f64,
    pub ff_pct: f64,
    pub dsp_pct: f64,
    pub bram_pct: f64,
}

// ---- calibration constants (see module docs) -----------------------------

/// Fraction of MAC units mapped to DSP slices; the remainder goes to
/// LUT fabric (the paper's x1.2 headroom factor in Sec. 3.4).
const DSP_SHARE: f64 = 0.67;
/// LUTs per LUT-fabric MAC (13x10-bit multiplier + adder).
const LUT_PER_MAC: u64 = 110;
/// Control/addressing LUTs per CNN instance.
const LUT_INSTANCE_CTRL: u64 = 3_600;
/// LUTs per stream module (SSM or MSM, incl. OGM/ORM amortized).
const LUT_STREAM: u64 = 2_500;
/// Static infrastructure (clocking, AXI, I/O).
const LUT_BASE: u64 = 90_000;
/// Pipeline registers per instance (per MAC-stage flop chains).
const FF_PER_INSTANCE: u64 = 14_200;
/// Registers per stream module.
const FF_STREAM: u64 = 1_000;
const FF_BASE: u64 = 15_000;
/// 36 Kb BRAMs per stream module (sub-sequence double buffers).
const BRAM_STREAM: u64 = 16;
/// BRAMs per instance (weight/line buffers) in the HT design.
const BRAM_INSTANCE: f64 = 1.5;
const BRAM_BASE: u64 = 6;

/// MAC operations the engine performs per clock cycle for one instance
/// producing `V_p` samples/cycle (the HT configuration, Sec. 5.1).
pub fn macs_per_cycle_full(cfg: &CnnTopologyCfg) -> f64 {
    // One pass consumes V_p * N_os samples and produces V_p symbols in
    // N_os... the streaming engine sustains V_p samples/cycle, i.e.
    // V_p / N_os symbols/cycle at MAC_sym MACs per symbol.
    cfg.mac_per_symbol() * cfg.vp as f64 / cfg.n_os as f64
}

/// High-throughput design: `n_i` fully parallel instances plus the
/// SSM/MSM partition tree (2 * (n_i - 1) stream modules).
pub fn ht_design(cfg: &CnnTopologyCfg, n_i: u64) -> ResourceUsage {
    let macs = macs_per_cycle_full(cfg);
    let dsp_per_inst = macs * DSP_SHARE;
    let lut_macs_per_inst = (macs * (1.0 - DSP_SHARE)).ceil() as u64;
    let stream_modules = if n_i > 1 { 2 * (n_i - 1) } else { 0 };

    ResourceUsage {
        dsps: (dsp_per_inst * n_i as f64).round() as u64,
        luts: LUT_BASE
            + n_i * (lut_macs_per_inst * LUT_PER_MAC + LUT_INSTANCE_CTRL)
            + stream_modules * LUT_STREAM,
        ffs: FF_BASE + n_i * FF_PER_INSTANCE + stream_modules * FF_STREAM,
        brams: BRAM_BASE
            + (n_i as f64 * BRAM_INSTANCE).round() as u64
            + stream_modules * BRAM_STREAM,
    }
}

/// Low-power design: one instance with a reduced-DOP engine on a small
/// device (Fig. 8a).  `dev` bounds the DSP pool; overflow MACs go to
/// LUTs; trainable parameters live in BRAM at small DOP and in LUTs at
/// large DOP (observed Vivado HLS behaviour, Sec. 5.2).
pub fn lp_design(cfg: &CnnTopologyCfg, dop: Dop, dev: &Device) -> ResourceUsage {
    // One shared conv engine time-multiplexed across layers (the LP
    // design point; the HT design instead pipelines one engine per
    // layer, Sec. 5.1).
    let macs = dop.total() as u64;
    let dsps = macs.min(dev.dsps);
    let overflow = macs - dsps;

    let params: u64 = cfg
        .layer_channels()
        .iter()
        .map(|&(ci, co)| (ci * co * cfg.kernel + co) as u64)
        .sum();
    // 13-bit words: ~2.8 params per LUT as distributed RAM.
    let (param_brams, param_luts) =
        if dop.total() <= 25 { ((params * 13).div_ceil(36_000) + 7, 0) } else { (1, params / 2) };

    ResourceUsage {
        dsps,
        luts: 1_200 + dop.total() as u64 * 14 + overflow * LUT_PER_MAC + param_luts,
        ffs: 2_400 + macs * 60,
        brams: 2 + param_brams,
    }
}

/// Paper's hardware-aware complexity ceiling (Sec. 3.4):
/// `MAC_sym,max = DSP_avail / T_req * f_clk * 1.2`.
pub fn mac_sym_max(dev: &Device, t_req_baud: f64) -> f64 {
    dev.dsps as f64 / t_req_baud * dev.f_clk_hz * 1.2
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::device::{XC7S25, XCVU13P};

    #[test]
    fn table1_reproduction() {
        // Paper Table 1 (64 instances on XCVU13P):
        //   LUT 1 176 156 (68.06%), FF 1 050 179 (30.39%),
        //   DSP 9 648 (78.52%), BRAM 2 118 (78.79%).
        let u = ht_design(&CnnTopologyCfg::SELECTED, 64);
        let pct = u.utilization(&XCVU13P);
        assert_eq!(u.dsps, 9_648, "DSP calibrated exactly");
        assert!((pct.lut_pct - 68.06).abs() < 5.0, "LUT {:.1}%", pct.lut_pct);
        assert!((pct.ff_pct - 30.39).abs() < 5.0, "FF {:.1}%", pct.ff_pct);
        assert!((pct.bram_pct - 78.79).abs() < 5.0, "BRAM {:.1}%", pct.bram_pct);
        assert!(u.fits(&XCVU13P));
    }

    #[test]
    fn ht_scales_with_instances() {
        let cfg = CnnTopologyCfg::SELECTED;
        let u32 = ht_design(&cfg, 32);
        let u64_ = ht_design(&cfg, 64);
        assert!(u64_.dsps > u32.dsps && u64_.luts > u32.luts && u64_.brams > u32.brams);
        // 128 instances must NOT fit (the paper could not go beyond 64).
        assert!(!ht_design(&cfg, 128).fits(&XCVU13P));
    }

    #[test]
    fn lp_dop225_overflows_luts() {
        // Fig. 8a: at DOP 225 all DSPs are used and LUTs exceed 100%.
        let cfg = CnnTopologyCfg::SELECTED;
        let dop = Dop { i: 5, o: 5, k: 9 };
        let u = lp_design(&cfg, dop, &XC7S25);
        assert_eq!(u.dsps, XC7S25.dsps);
        assert!(u.utilization(&XC7S25).lut_pct > 100.0);
    }

    #[test]
    fn lp_small_dops_fit_and_use_bram() {
        let cfg = CnnTopologyCfg::SELECTED;
        for t in [1usize, 5, 10, 25] {
            let dop = Dop::enumerate(&cfg).into_iter().find(|d| d.total() == t).unwrap();
            let u = lp_design(&cfg, dop, &XC7S25);
            assert!(u.fits(&XC7S25), "DOP {t} should fit");
            assert!(u.brams >= 8, "params in BRAM at DOP {t}");
        }
    }

    #[test]
    fn lp_resources_monotone_in_dop() {
        let cfg = CnnTopologyCfg::SELECTED;
        let sweep = Dop::paper_sweep(&cfg);
        let luts: Vec<u64> = sweep.iter().map(|&d| lp_design(&cfg, d, &XC7S25).luts).collect();
        for w in luts.windows(2) {
            assert!(w[1] >= w[0], "LUTs must grow with DOP: {luts:?}");
        }
    }

    #[test]
    fn mac_ceiling_matches_fig2_line() {
        // 12288 DSP / 40 GBd * 200 MHz * 1.2 = 73.7 -> the paper's Fig. 2
        // red line sits near the selected model's 56.25 MAC/sym.
        let ceiling = mac_sym_max(&XCVU13P, 40e9);
        assert!(ceiling > 56.25, "selected model must satisfy the ceiling: {ceiling}");
        assert!(ceiling < 200.0);
    }
}
