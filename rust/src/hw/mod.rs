//! FPGA resource/power models and platform performance models.
//!
//! The paper evaluates on Vivado place-and-route results (Table 1,
//! Fig. 8) and on measured GPU/CPU baselines (Figs. 13-15).  Neither a
//! Xilinx toolchain nor the GPUs are available here, so these are
//! *analytic models calibrated to the paper's published data points*
//! (DESIGN.md §3): the resource model reproduces Table 1 at N_i = 64 by
//! construction and is then exercised across N_i / DOP for the sweeps;
//! the platform models use the classic launch-overhead + roofline
//! saturation form that produces the paper's reported shapes.

pub mod device;
pub mod dop;
pub mod platform;
pub mod power;
pub mod resource;
