//! FPGA device resource inventories.


/// An FPGA part's available resources.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Device {
    pub name: &'static str,
    pub luts: u64,
    pub ffs: u64,
    pub dsps: u64,
    /// 36 Kb block RAMs.
    pub brams: u64,
    /// Achievable clock for this architecture (paper: timing violations
    /// above 200 MHz on the VU13P design).
    pub f_clk_hz: f64,
}

/// Xilinx XCVU13P — the paper's high-throughput target.
///
/// Totals back-derived from Table 1 (absolute vs %% utilization):
/// 1 176 156 / 68.06% = 1 728 000 LUTs, etc.
pub const XCVU13P: Device = Device {
    name: "XCVU13P",
    luts: 1_728_000,
    ffs: 3_456_000,
    dsps: 12_288,
    brams: 2_688,
    f_clk_hz: 200e6,
};

/// Xilinx XC7S25 (Spartan-7) — the paper's low-cost / low-power target.
pub const XC7S25: Device = Device {
    name: "XC7S25",
    luts: 14_600,
    ffs: 29_200,
    dsps: 80,
    brams: 45,
    f_clk_hz: 100e6,
};

impl Device {
    pub fn by_name(name: &str) -> Option<Device> {
        match name {
            "XCVU13P" => Some(XCVU13P),
            "XC7S25" => Some(XC7S25),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_totals_are_consistent() {
        // Table 1: 68.06% == 1 176 156 LUTs etc. — the percentages the
        // paper reports must reproduce from these totals.
        assert_eq!((1_176_156.0_f64 / XCVU13P.luts as f64 * 100.0).round() as i64, 68);
        assert_eq!((1_050_179.0_f64 / XCVU13P.ffs as f64 * 100.0).round() as i64, 30);
        assert_eq!((9_648.0_f64 / XCVU13P.dsps as f64 * 100.0).round() as i64, 79);
        assert_eq!((2_118.0_f64 / XCVU13P.brams as f64 * 100.0).round() as i64, 79);
    }

    #[test]
    fn lookup() {
        assert_eq!(Device::by_name("XC7S25"), Some(XC7S25));
        assert!(Device::by_name("nope").is_none());
    }
}
