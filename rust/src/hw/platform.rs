//! Platform performance models for the Figs. 13-15 comparison.
//!
//! The paper measures an Nvidia RTX 2080 Ti (PyTorch and TensorRT), an
//! Nvidia AGX Xavier (PyTorch and TensorRT) and an Intel i9-9900KF.
//! None of that hardware exists here, so each platform is a
//! launch-overhead + roofline model (DESIGN.md §3):
//!
//!   T(spb)      = spb / (t_launch + spb / rate_peak)   [sym/s]
//!   lambda(spb) = t_launch + spb / rate_peak            [s]
//!   P(spb)      = P_idle + (P_max - P_idle) * T(spb)/rate_peak
//!
//! Constants are calibrated to the paper's reported anchors: TensorRT
//! ~10x PyTorch at low SPB; RTX-TRT peaks at 12 GBd; the HT FPGA is
//! ~4500x faster than RTX-TRT at 400 SPB; GPU/CPU latency ~5x the HT
//! FPGA's at low SPB and up to 52x at high SPB; CPU peaks at 93 W, GPU
//! at 250 W.  The FPGA entries are *not* models — they come from the
//! timing model / measured pipeline (Sec. 6) and the power model.


/// A modeled conventional platform.
#[derive(Debug, Clone, Copy)]
pub struct PlatformModel {
    pub name: &'static str,
    /// Fixed per-batch overhead (kernel launch, host sync) in seconds.
    pub t_launch_s: f64,
    /// Saturated symbol rate (symbols/second).
    pub rate_peak: f64,
    pub p_idle_w: f64,
    pub p_max_w: f64,
}

impl PlatformModel {
    /// Throughput in symbols/s at a given batch size (symbols per batch).
    pub fn throughput(&self, spb: u64) -> f64 {
        let spb = spb as f64;
        spb / (self.t_launch_s + spb / self.rate_peak)
    }

    /// Per-batch latency in seconds.
    pub fn latency(&self, spb: u64) -> f64 {
        self.t_launch_s + spb as f64 / self.rate_peak
    }

    /// Power draw at a given batch size.
    pub fn power(&self, spb: u64) -> f64 {
        self.p_idle_w + (self.p_max_w - self.p_idle_w) * self.throughput(spb) / self.rate_peak
    }
}

/// RTX 2080 Ti running the PyTorch model.
pub const RTX_PYTORCH: PlatformModel = PlatformModel {
    name: "RTX 2080 Ti (PyTorch)",
    t_launch_s: 400e-6,
    rate_peak: 1.3e9,
    p_idle_w: 55.0,
    p_max_w: 250.0,
};

/// RTX 2080 Ti with the TensorRT-optimized engine.
pub const RTX_TENSORRT: PlatformModel = PlatformModel {
    name: "RTX 2080 Ti (TensorRT)",
    t_launch_s: 42e-6,
    rate_peak: 12.0e9,
    p_idle_w: 55.0,
    p_max_w: 250.0,
};

/// AGX Xavier running PyTorch.
pub const AGX_PYTORCH: PlatformModel = PlatformModel {
    name: "AGX Xavier (PyTorch)",
    t_launch_s: 1.2e-3,
    rate_peak: 0.12e9,
    p_idle_w: 9.0,
    p_max_w: 30.0,
};

/// AGX Xavier with TensorRT.
pub const AGX_TENSORRT: PlatformModel = PlatformModel {
    name: "AGX Xavier (TensorRT)",
    t_launch_s: 120e-6,
    rate_peak: 1.1e9,
    p_idle_w: 9.0,
    p_max_w: 30.0,
};

/// Intel i9-9900KF (vectorized CPU inference).
pub const CPU_I9: PlatformModel = PlatformModel {
    name: "Core i9-9900KF",
    t_launch_s: 60e-6,
    rate_peak: 0.25e9,
    p_idle_w: 28.0,
    p_max_w: 93.0,
};

/// All modeled platforms, in the paper's legend order.
pub const ALL: [PlatformModel; 5] =
    [RTX_PYTORCH, RTX_TENSORRT, AGX_PYTORCH, AGX_TENSORRT, CPU_I9];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_ramps_then_saturates() {
        for p in ALL {
            let low = p.throughput(16);
            let mid = p.throughput(10_000);
            let hi = p.throughput(100_000_000);
            assert!(low < mid && mid <= hi, "{}", p.name);
            assert!(hi <= p.rate_peak * 1.0001);
            assert!(hi >= p.rate_peak * 0.9, "{} saturates below peak", p.name);
        }
    }

    #[test]
    fn tensorrt_an_order_faster_at_low_spb() {
        // Paper Sec. 7.3.1: ~1 order of magnitude at low batch sizes.
        let r = RTX_TENSORRT.throughput(100) / RTX_PYTORCH.throughput(100);
        assert!((5.0..20.0).contains(&r), "RTX TRT/PT = {r}");
        let a = AGX_TENSORRT.throughput(100) / AGX_PYTORCH.throughput(100);
        assert!((5.0..20.0).contains(&a), "AGX TRT/PT = {a}");
    }

    #[test]
    fn ht_fpga_4500x_anchor() {
        // Paper: HT FPGA (40.96 GBd net at 512 SPB) ~4500x RTX-TRT at
        // 400 SPB.
        let fpga = 40.96e9;
        let ratio = fpga / RTX_TENSORRT.throughput(400);
        assert!((2000.0..8000.0).contains(&ratio), "anchor ratio {ratio}");
    }

    #[test]
    fn rtx_trt_peak_12gbd() {
        assert!((RTX_TENSORRT.throughput(1_000_000_000) / 1e9 - 12.0).abs() < 0.5);
    }

    #[test]
    fn power_between_idle_and_max() {
        for p in ALL {
            for spb in [1u64, 1000, 1_000_000] {
                let w = p.power(spb);
                assert!(w >= p.p_idle_w && w <= p.p_max_w, "{} {w}", p.name);
            }
        }
        assert!((CPU_I9.power(u64::MAX / 2) - 93.0).abs() < 1.0);
    }

    #[test]
    fn latency_grows_with_batch() {
        for p in ALL {
            assert!(p.latency(100_000) > p.latency(100));
        }
    }
}
