//! Degree-of-parallelism semantics (Sec. 5.2).
//!
//! Per instance, parallelism applies to input channels (`DOP_I`), output
//! channels (`DOP_O`) and the kernel (`DOP_K`), with
//! `DOP = DOP_I * DOP_O * DOP_K`, constrained by
//! `I_c % DOP_I == 0`, `O_c % DOP_O == 0`, `DOP_K in {1, K}`.

use crate::equalizer::weights::CnnTopologyCfg;

/// A concrete parallelism assignment for the convolution engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Dop {
    pub i: usize,
    pub o: usize,
    pub k: usize,
}

impl Dop {
    pub fn total(&self) -> usize {
        self.i * self.o * self.k
    }

    /// Is this assignment legal for the topology (Sec. 5.2 constraints)?
    ///
    /// The hidden layers have `I_c = O_c = C`; the constraint set the
    /// paper states uses the hidden-layer channel count and the kernel.
    pub fn valid_for(&self, cfg: &CnnTopologyCfg) -> bool {
        let c = cfg.channels;
        let divides = |n: usize, d: usize| d >= 1 && n % d == 0;
        divides(c, self.i) && (divides(c, self.o) || divides(cfg.vp, self.o))
            && (self.k == 1 || self.k == cfg.kernel)
    }

    /// Enumerate all legal DOPs for a topology, ascending by total.
    pub fn enumerate(cfg: &CnnTopologyCfg) -> Vec<Dop> {
        let mut divs_c: Vec<usize> = (1..=cfg.channels).filter(|d| cfg.channels % d == 0).collect();
        let mut divs_o: Vec<usize> = divs_c.clone();
        divs_o.extend((1..=cfg.vp).filter(|d| cfg.vp % d == 0));
        divs_o.sort_unstable();
        divs_o.dedup();
        divs_c.sort_unstable();
        let mut out = Vec::new();
        for &i in &divs_c {
            for &o in &divs_o {
                for k in [1, cfg.kernel] {
                    let d = Dop { i, o, k };
                    if d.valid_for(cfg) {
                        out.push(d);
                    }
                }
            }
        }
        out.sort_by_key(|d| d.total());
        out.dedup_by_key(|d| d.total());
        out
    }

    /// The paper's Fig. 8 sweep points for the selected topology.
    pub fn paper_sweep(cfg: &CnnTopologyCfg) -> Vec<Dop> {
        [1usize, 5, 10, 25, 225]
            .iter()
            .filter_map(|&t| Self::enumerate(cfg).into_iter().find(|d| d.total() == t))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals_multiply() {
        assert_eq!(Dop { i: 5, o: 5, k: 9 }.total(), 225);
    }

    #[test]
    fn paper_dops_exist_for_selected() {
        let cfg = CnnTopologyCfg::SELECTED;
        let sweep = Dop::paper_sweep(&cfg);
        let totals: Vec<usize> = sweep.iter().map(|d| d.total()).collect();
        // The paper lists DOP in {1, 5, 10, 25, 225} for this topology.
        assert_eq!(totals, vec![1, 5, 10, 25, 225]);
    }

    #[test]
    fn kernel_dop_is_binary() {
        let cfg = CnnTopologyCfg::SELECTED;
        assert!(!Dop { i: 1, o: 1, k: 3 }.valid_for(&cfg));
        assert!(Dop { i: 1, o: 1, k: 9 }.valid_for(&cfg));
        assert!(Dop { i: 1, o: 1, k: 1 }.valid_for(&cfg));
    }

    #[test]
    fn channel_divisibility() {
        let cfg = CnnTopologyCfg::SELECTED; // C = 5
        assert!(!Dop { i: 3, o: 1, k: 1 }.valid_for(&cfg));
        assert!(Dop { i: 5, o: 5, k: 1 }.valid_for(&cfg));
    }

    #[test]
    fn enumerate_sorted_unique() {
        let cfg = CnnTopologyCfg::SELECTED;
        let all = Dop::enumerate(&cfg);
        let totals: Vec<usize> = all.iter().map(|d| d.total()).collect();
        let mut sorted = totals.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(totals, sorted);
        assert!(totals.contains(&1) && totals.contains(&225));
    }
}
