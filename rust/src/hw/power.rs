//! Power models (Fig. 8b, Fig. 15).
//!
//! Stand-in for Vivado's power estimator / PyJoules / jetson-stats
//! (DESIGN.md §3): dynamic power scales with active MAC throughput,
//! `P = P_static + e_mac * MACs_per_second`, with constants calibrated
//! to the paper's endpoints (LP design 0.1 -> 0.2 W across the DOP
//! sweep; HT design approximately 2x the AGX Xavier's envelope).

use super::device::Device;
use super::dop::Dop;
use super::resource::macs_per_cycle_full;
use crate::equalizer::weights::CnnTopologyCfg;

/// Energy per MAC-op (J) for the LP fabric (13x10-bit fixed point).
const E_MAC_LP: f64 = 4.5e-12;
/// Energy per MAC for the HT fabric (UltraScale+, higher toggle rates,
/// wide streams).
const E_MAC_HT: f64 = 9.5e-12;
/// Static power of the Spartan-7 design (clock tree + config).
const P_STATIC_LP: f64 = 0.094;
/// Static power of the VU13P design (serdes, clocking, BRAM standby).
const P_STATIC_HT: f64 = 7.0;

/// LP design dynamic power at a given DOP (one instance).
pub fn lp_power_w(_cfg: &CnnTopologyCfg, dop: Dop, dev: &Device) -> f64 {
    // Shared engine: DOP MACs toggle per cycle.
    let macs_per_s = dop.total() as f64 * dev.f_clk_hz;
    P_STATIC_LP + E_MAC_LP * macs_per_s
}

/// LP design net throughput in symbols/s at a given DOP: the engine
/// needs `ceil(layer_macs / DOP)` cycles per layer per pass of
/// `V_p` symbols (Sec. 5.2 time-multiplexed engine).
pub fn lp_throughput_baud(cfg: &CnnTopologyCfg, dop: Dop, dev: &Device) -> f64 {
    let pass_samples = cfg.vp * cfg.n_os;
    let mut w = pass_samples;
    let mut cycles = 0u64;
    for (l, stride) in cfg.strides().iter().enumerate() {
        let w_out = w / stride; // pass-granular (padding amortized away)
        let (cin, cout) = cfg.layer_channels()[l];
        let layer_macs = (w_out.max(1) * cin * cout * cfg.kernel) as u64;
        // The engine cannot exploit more parallelism than the layer has.
        let eff_dop = (dop.total() as u64).min(layer_macs);
        cycles += layer_macs.div_ceil(eff_dop);
        w = w_out;
    }
    cfg.vp as f64 * dev.f_clk_hz / cycles as f64
}

/// HT design power with `n_i` full-DOP instances.
pub fn ht_power_w(cfg: &CnnTopologyCfg, n_i: u64, dev: &Device) -> f64 {
    let macs_per_s = macs_per_cycle_full(cfg) * n_i as f64 * dev.f_clk_hz;
    P_STATIC_HT + E_MAC_HT * macs_per_s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::device::{XC7S25, XCVU13P};

    #[test]
    fn lp_power_range_matches_fig8b() {
        // Paper: one XC7S25 instance spans ~0.1 W .. ~0.2 W across DOPs.
        let cfg = CnnTopologyCfg::SELECTED;
        let sweep = Dop::paper_sweep(&cfg);
        let p_min = lp_power_w(&cfg, sweep[0], &XC7S25);
        let p_max = lp_power_w(&cfg, *sweep.last().unwrap(), &XC7S25);
        assert!((0.08..=0.12).contains(&p_min), "P(DOP=1) = {p_min}");
        assert!((0.15..=0.45).contains(&p_max), "P(DOP=225) = {p_max}");
    }

    #[test]
    fn lp_throughput_monotone_and_in_mbit_range() {
        // Paper: ~4 .. ~110 Mbit/s across the DOP sweep (PAM-2: 1 bit/sym).
        let cfg = CnnTopologyCfg::SELECTED;
        let sweep = Dop::paper_sweep(&cfg);
        let t: Vec<f64> = sweep.iter().map(|&d| lp_throughput_baud(&cfg, d, &XC7S25)).collect();
        for w in t.windows(2) {
            assert!(w[1] > w[0], "throughput must grow with DOP: {t:?}");
        }
        assert!(t[0] > 0.3e6 && t[0] < 10e6, "low end {:.2e}", t[0]);
        assert!(*t.last().unwrap() > 50e6 && *t.last().unwrap() < 400e6);
    }

    #[test]
    fn ht_power_plausible() {
        // Fig. 15: HT FPGA ~2x the AGX (~15 W envelope) and far below the
        // 250 W GPU.
        let p = ht_power_w(&CnnTopologyCfg::SELECTED, 64, &XCVU13P);
        assert!((20.0..60.0).contains(&p), "HT power {p} W");
    }

    #[test]
    fn power_scales_with_instances() {
        let cfg = CnnTopologyCfg::SELECTED;
        assert!(ht_power_w(&cfg, 64, &XCVU13P) > ht_power_w(&cfg, 8, &XCVU13P));
    }
}
