//! Communication-channel simulators (Sec. 2 substrates).
//!
//! The paper's high-throughput channel is an *experimental* 40 GBd PAM-2
//! IM/DD optical link; the low-cost channel is the simulated Proakis-B
//! "magnetic recording" channel.  Both are rebuilt here so the Rust
//! coordinator can generate live receiver streams on the serving side —
//! mirroring the Python build-time simulators in
//! `python/compile/channels.py` (same impairment mechanisms, same
//! oversampling, Mersenne-Twister PRBS per the paper's reference [18]).

pub mod awgn;
pub mod drift;
pub mod fft;
pub mod filter;
pub mod imdd;
pub mod mt19937;
pub mod proakis;

/// Oversampling factor used throughout the paper (N_os).
pub const N_OS: usize = 2;

/// One simulated transmission: receiver samples plus ground truth.
///
/// `rx` carries `N_OS` samples per symbol, aligned so sample `N_OS * i`
/// corresponds to symbol `i` (ideal timing recovery, as in the paper's
/// offline pipeline).
#[derive(Debug, Clone)]
pub struct ChannelData {
    /// Received samples at `N_OS` x symbol rate, normalized.
    pub rx: Vec<f32>,
    /// Transmitted PAM-2 symbols in {-1, +1}.
    pub symbols: Vec<f32>,
}

/// A channel model that can synthesize transmissions.
pub trait Channel {
    /// Simulate `n_sym` symbols with the given PRBS seed.
    fn transmit(&self, n_sym: usize, seed: u32) -> ChannelData;
    /// Human-readable channel name (matches artifact naming).
    fn name(&self) -> &'static str;
}

/// PAM-2 PRBS in {-1, +1} from a Mersenne-Twister stream (paper [18]).
pub fn prbs(n_sym: usize, seed: u32) -> Vec<f32> {
    let mut mt = mt19937::Mt19937::new(seed);
    (0..n_sym)
        .map(|_| if mt.next_u32() & 0x8000_0000 != 0 { 1.0 } else { -1.0 })
        .collect()
}

/// Upsample symbols by `sps` (zeros between symbols).
pub fn upsample(symbols: &[f32], sps: usize) -> Vec<f32> {
    let mut out = vec![0.0; symbols.len() * sps];
    for (i, &s) in symbols.iter().enumerate() {
        out[i * sps] = s;
    }
    out
}

/// Remove mean and scale to unit standard deviation, in place.
pub fn normalize(x: &mut [f32]) {
    let n = x.len() as f64;
    let mean = x.iter().map(|&v| v as f64).sum::<f64>() / n;
    let var = x.iter().map(|&v| (v as f64 - mean).powi(2)).sum::<f64>() / n;
    let std = var.sqrt().max(1e-12);
    for v in x.iter_mut() {
        *v = ((*v as f64 - mean) / std) as f32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prbs_is_deterministic() {
        assert_eq!(prbs(256, 7), prbs(256, 7));
        assert_ne!(prbs(256, 7), prbs(256, 8));
    }

    #[test]
    fn prbs_is_binary_and_balanced() {
        let s = prbs(20_000, 0);
        assert!(s.iter().all(|&v| v == 1.0 || v == -1.0));
        let mean: f32 = s.iter().sum::<f32>() / s.len() as f32;
        assert!(mean.abs() < 0.05, "unbalanced: {mean}");
    }

    #[test]
    fn upsample_places_symbols() {
        let u = upsample(&[1.0, -1.0], 2);
        assert_eq!(u, vec![1.0, 0.0, -1.0, 0.0]);
    }

    #[test]
    fn normalize_zero_mean_unit_std() {
        let mut x: Vec<f32> = (0..1000).map(|i| (i as f32) * 0.01 + 3.0).collect();
        normalize(&mut x);
        let mean: f32 = x.iter().sum::<f32>() / x.len() as f32;
        let var: f32 = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
        assert!(mean.abs() < 1e-4);
        assert!((var - 1.0).abs() < 1e-3);
    }
}
