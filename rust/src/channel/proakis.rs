//! Proakis-B "magnetic recording" channel (Sec. 2.2).
//!
//! Linear bad-quality channel with T-spaced impulse response
//! `h = [0.407, 0.815, 0.407]` (Proakis, Digital Communications,
//! Ch. 9.4-3), raised-cosine pulse shaping and AWGN at 20 dB — the
//! paper's low-cost / low-power application scenario.

use super::awgn::add_awgn;
use super::filter::{convolve_same, rc_taps};
use super::{normalize, prbs, upsample, Channel, ChannelData, N_OS};

/// The Proakis-B discrete impulse response (symbol-spaced).
pub const H_PROAKIS_B: [f64; 3] = [0.407, 0.815, 0.407];

/// Proakis-B channel parameters.
#[derive(Debug, Clone)]
pub struct ProakisBChannel {
    /// Receiver SNR in dB (paper models the bad channel at 20 dB).
    pub snr_db: f64,
    /// RC roll-off.
    pub rc_beta: f64,
    /// RC span in symbols.
    pub rc_span: usize,
}

impl Default for ProakisBChannel {
    fn default() -> Self {
        Self { snr_db: 20.0, rc_beta: 0.3, rc_span: 16 }
    }
}

impl Channel for ProakisBChannel {
    fn transmit(&self, n_sym: usize, seed: u32) -> ChannelData {
        let symbols = prbs(n_sym, seed);
        let up = upsample(&symbols, N_OS);
        let up_f64: Vec<f64> = up.iter().map(|&v| v as f64).collect();
        let shaped = convolve_same(&up_f64, &rc_taps(self.rc_beta, self.rc_span, N_OS));

        // T-spaced channel IR on the N_os grid (zeros between taps).
        let mut h_up = vec![0.0; (H_PROAKIS_B.len() - 1) * N_OS + 1];
        for (i, &h) in H_PROAKIS_B.iter().enumerate() {
            h_up[i * N_OS] = h;
        }
        let mut chan = convolve_same(&shaped, &h_up);
        let n = chan.len() as f64;
        let var = chan.iter().map(|v| v * v).sum::<f64>() / n;
        let std = var.sqrt().max(1e-12);
        for v in chan.iter_mut() {
            *v /= std;
        }

        add_awgn(&mut chan, self.snr_db, seed.wrapping_add(1));
        let mut rx: Vec<f32> = chan.iter().map(|&v| v as f32).collect();
        normalize(&mut rx);
        ChannelData { rx, symbols }
    }

    fn name(&self) -> &'static str {
        "proakis"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes() {
        let d = ProakisBChannel::default().transmit(3000, 0);
        assert_eq!(d.rx.len(), 6000);
        assert_eq!(d.symbols.len(), 3000);
    }

    #[test]
    fn deterministic() {
        let ch = ProakisBChannel::default();
        assert_eq!(ch.transmit(500, 1).rx, ch.transmit(500, 1).rx);
    }

    #[test]
    fn linearity_of_noise_free_chain() {
        // Superposition on the symbol->rx map (noise-free, fixed seeds
        // only differ in symbol sequence) is implied by convolution;
        // verify via impulse response extraction: a single +1 symbol in
        // a zero sequence must produce the RC*h_up response.
        let _ch = ProakisBChannel { snr_db: 200.0, ..Default::default() };
        // With snr 200 dB the noise is negligible.
        let d = ProakisBChannel { snr_db: 200.0, ..Default::default() }.transmit(2000, 0);
        // Reconstruct rx from symbols by direct convolution and compare.
        let up: Vec<f64> = {
            let u = upsample(&d.symbols, N_OS);
            u.iter().map(|&v| v as f64).collect()
        };
        let shaped = convolve_same(&up, &rc_taps(0.3, 16, N_OS));
        let mut h_up = vec![0.0; 5];
        h_up[0] = H_PROAKIS_B[0];
        h_up[2] = H_PROAKIS_B[1];
        h_up[4] = H_PROAKIS_B[2];
        let chan = convolve_same(&shaped, &h_up);
        // rx is a normalized version of chan: correlation must be ~1.
        let rx: Vec<f64> = d.rx.iter().map(|&v| v as f64).collect();
        let num: f64 = rx.iter().zip(&chan).map(|(a, b)| a * b).sum();
        let da: f64 = rx.iter().map(|v| v * v).sum::<f64>().sqrt();
        let db: f64 = chan.iter().map(|v| v * v).sum::<f64>().sqrt();
        assert!(num / (da * db) > 0.999, "chain mismatch: {}", num / (da * db));
    }

    #[test]
    fn snr_affects_quality() {
        let lo = ProakisBChannel { snr_db: 5.0, ..Default::default() }.transmit(4000, 0);
        let hi = ProakisBChannel { snr_db: 30.0, ..Default::default() }.transmit(4000, 0);
        let c = |d: &ChannelData| {
            let xs: Vec<f64> = d.rx.iter().step_by(2).map(|&v| v as f64).collect();
            let ys: Vec<f64> = d.symbols.iter().map(|&v| v as f64).collect();
            let n = xs.len() as f64;
            let mx = xs.iter().sum::<f64>() / n;
            let my = ys.iter().sum::<f64>() / n;
            let cov: f64 =
                xs.iter().zip(&ys).map(|(a, b)| (a - mx) * (b - my)).sum::<f64>() / n;
            let sx = (xs.iter().map(|a| (a - mx).powi(2)).sum::<f64>() / n).sqrt();
            let sy = (ys.iter().map(|b| (b - my).powi(2)).sum::<f64>() / n).sqrt();
            (cov / (sx * sy)).abs()
        };
        assert!(c(&hi) > c(&lo));
    }
}
