//! Slowly drifting ISI channel — the adaptation-loop test substrate.
//!
//! The paper's channels are stationary: weights trained offline stay
//! valid forever.  Real links drift (temperature, polarization, aging),
//! which is what the companion trainable-equalizer work (arXiv
//! 2304.06987, PAPERS.md) adapts to online.  This channel makes that
//! failure mode reproducible in-tree: a pulse-shaped PAM-2 stream with
//! two post-cursor ISI taps whose energy *rotates* between a one-symbol
//! and a two-symbol lag as
//!
//! ```text
//! a1(k) = A * cos(phase0 + rate * k)     at lag N_OS samples
//! a2(k) = A * sin(phase0 + rate * k)     at lag 2 * N_OS samples
//! ```
//!
//! with `k` the absolute symbol index.  A static equalizer trained at
//! `k = 0` equalizes `a1 = A, a2 = 0`; thousands of symbols later the
//! channel it was trained for no longer exists and its BER climbs.  The
//! decision-directed LMS loop ([`crate::runtime::adapt`]) re-publishes
//! adapted taps through the registry and tracks the rotation —
//! `repro adapt` plots both trajectories.
//!
//! [`DriftChannel::transmit_from`] takes the absolute starting symbol
//! index so consecutive blocks continue the same drift trajectory; the
//! [`Channel`] impl starts at zero like every stationary channel.

use super::awgn::add_awgn;
use super::filter::{convolve_same, rrc_taps};
use super::{normalize, prbs, upsample, Channel, ChannelData, N_OS};

/// Drifting two-tap post-cursor ISI channel parameters.
#[derive(Debug, Clone)]
pub struct DriftChannel {
    /// Receiver SNR in dB on the impaired signal.
    pub snr_db: f64,
    /// RRC roll-off for the transmit pulse shaping.
    pub rrc_beta: f64,
    /// RRC span in symbols.
    pub rrc_span: usize,
    /// Peak post-cursor amplitude `A` (split between the two lags by
    /// the rotation phase).
    pub isi_amplitude: f64,
    /// Rotation phase at symbol index 0, in radians.
    pub phase0: f64,
    /// Rotation rate in radians per symbol.  The default sweeps ~0.2
    /// rad across a 4000-symbol block — slow against an LMS time
    /// constant, fatal to a static equalizer over a long run.
    pub drift_rate: f64,
}

impl Default for DriftChannel {
    fn default() -> Self {
        Self {
            snr_db: 22.0,
            rrc_beta: 0.2,
            rrc_span: 16,
            isi_amplitude: 0.6,
            phase0: 0.0,
            drift_rate: 5e-5,
        }
    }
}

impl DriftChannel {
    /// Rotation phase at absolute symbol index `k`.
    fn phase(&self, k: f64) -> f64 {
        self.phase0 + self.drift_rate * k
    }

    /// Simulate `n_sym` symbols starting at absolute symbol index
    /// `start_sym` of the drift trajectory — block `b` of a streaming
    /// run passes `start_sym = b * block_len` so the rotation continues
    /// across block boundaries instead of restarting.
    pub fn transmit_from(&self, n_sym: usize, seed: u32, start_sym: u64) -> ChannelData {
        let symbols = prbs(n_sym, seed);

        // TX: upsample -> RRC pulse shaping (same front end as imdd).
        let up = upsample(&symbols, N_OS);
        let up_f64: Vec<f64> = up.iter().map(|&v| v as f64).collect();
        let taps = rrc_taps(self.rrc_beta, self.rrc_span, N_OS);
        let shaped = convolve_same(&up_f64, &taps);

        // Drifting post-cursor ISI on the shaped signal.  The phase is
        // a function of the absolute symbol index, so the two cursor
        // amplitudes trade energy as the stream progresses.
        let n = shaped.len();
        let mut rx: Vec<f64> = Vec::with_capacity(n);
        for k in 0..n {
            let phi = self.phase(start_sym as f64 + (k / N_OS) as f64);
            let mut v = shaped[k];
            if k >= N_OS {
                v += self.isi_amplitude * phi.cos() * shaped[k - N_OS];
            }
            if k >= 2 * N_OS {
                v += self.isi_amplitude * phi.sin() * shaped[k - 2 * N_OS];
            }
            rx.push(v);
        }

        // Unit-variance before noise injection so snr_db means the
        // same thing at every drift phase.
        let mean = rx.iter().sum::<f64>() / rx.len() as f64;
        let var = rx.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / rx.len() as f64;
        let std = var.sqrt().max(1e-12);
        for v in rx.iter_mut() {
            *v = (*v - mean) / std;
        }

        add_awgn(&mut rx, self.snr_db, seed.wrapping_add(1));
        let mut rx32: Vec<f32> = rx.iter().map(|&v| v as f32).collect();
        normalize(&mut rx32);

        ChannelData { rx: rx32, symbols }
    }
}

impl Channel for DriftChannel {
    fn transmit(&self, n_sym: usize, seed: u32) -> ChannelData {
        self.transmit_from(n_sym, seed, 0)
    }

    fn name(&self) -> &'static str {
        "drift"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::f64::consts::FRAC_PI_2;

    #[test]
    fn shapes_and_rate() {
        let d = DriftChannel::default().transmit(4000, 0);
        assert_eq!(d.rx.len(), 4000 * N_OS);
        assert_eq!(d.symbols.len(), 4000);
    }

    #[test]
    fn deterministic_and_phase_continuous() {
        let ch = DriftChannel::default();
        let a = ch.transmit_from(1000, 3, 5000);
        let b = ch.transmit_from(1000, 3, 5000);
        assert_eq!(a.rx, b.rx);
        assert_eq!(a.symbols, b.symbols);
        // Same seed at a different trajectory point: same symbols,
        // different impairment.
        let c = ch.transmit_from(1000, 3, 50_000);
        assert_eq!(a.symbols, c.symbols);
        assert_ne!(a.rx, c.rx);
    }

    #[test]
    fn normalized_output() {
        let d = DriftChannel::default().transmit(20_000, 0);
        let n = d.rx.len() as f64;
        let mean = d.rx.iter().map(|&v| v as f64).sum::<f64>() / n;
        let var = d.rx.iter().map(|&v| (v as f64 - mean).powi(2)).sum::<f64>() / n;
        assert!(mean.abs() < 0.05);
        assert!((var - 1.0).abs() < 0.1);
    }

    #[test]
    fn symbol_correlation_present() {
        let d = DriftChannel::default().transmit(20_000, 0);
        let xs: Vec<f64> = d.rx.iter().step_by(N_OS).map(|&v| v as f64).collect();
        let ys: Vec<f64> = d.symbols.iter().map(|&v| v as f64).collect();
        let c = corr(&xs, &ys);
        assert!(c.abs() > 0.3, "decorrelated: {c}");
    }

    #[test]
    fn drift_rotates_cursor_energy() {
        // Freeze the drift within a block (tiny rate) and compare two
        // trajectory points a quarter-rotation apart: at phase 0 the
        // ISI sits on the one-symbol lag, at pi/2 on the two-symbol
        // lag.
        let ch = DriftChannel { snr_db: 40.0, drift_rate: 1e-9, ..Default::default() };
        let quarter = (FRAC_PI_2 / ch.drift_rate) as u64;
        let at0 = ch.transmit_from(20_000, 0, 0);
        let at90 = ch.transmit_from(20_000, 0, quarter);
        // The even-length RRC (span * N_OS taps) has a half-sample group
        // delay through convolve_same, so symbol peaks land on odd rx
        // indices; sample that phase or the direct-path midpoint energy
        // swamps both cursors.
        let lag = |d: &ChannelData, by: usize| {
            let xs: Vec<f64> =
                d.rx.iter().skip(1 + by * N_OS).step_by(N_OS).map(|&v| v as f64).collect();
            let ys: Vec<f64> =
                d.symbols.iter().take(xs.len()).map(|&v| v as f64).collect();
            corr(&xs, &ys)
        };
        // rx sample at symbol i+1 carries symbol i through cursor a1…
        assert!(lag(&at0, 1).abs() > 2.0 * lag(&at90, 1).abs(), "lag-1 cursor did not fade");
        // …and at symbol i+2 through cursor a2, a quarter turn later.
        assert!(lag(&at90, 2).abs() > 2.0 * lag(&at0, 2).abs(), "lag-2 cursor did not appear");
    }

    fn corr(a: &[f64], b: &[f64]) -> f64 {
        let n = a.len().min(b.len()) as f64;
        let ma = a.iter().sum::<f64>() / n;
        let mb = b.iter().sum::<f64>() / n;
        let cov: f64 = a.iter().zip(b).map(|(x, y)| (x - ma) * (y - mb)).sum::<f64>() / n;
        let sa = (a.iter().map(|x| (x - ma).powi(2)).sum::<f64>() / n).sqrt();
        let sb = (b.iter().map(|y| (y - mb).powi(2)).sum::<f64>() / n).sqrt();
        cov / (sa * sb)
    }
}
