//! Radix-2 complex FFT — the DSP substrate for the chromatic-dispersion
//! all-pass filter of the IM/DD simulator.
//!
//! The paper's experimental link gets its nonlinearity from CD acting on
//! the optical *field* followed by square-law detection; simulating that
//! needs a frequency-domain all-pass, hence an FFT.  Iterative in-place
//! Cooley-Tukey over power-of-two lengths is sufficient (the simulator
//! pads to the next power of two and discards the wrap-around border).

use std::f64::consts::PI;

/// Complex number (f64) — minimal, avoids pulling in a numerics crate.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct C64 {
    pub re: f64,
    pub im: f64,
}

impl C64 {
    pub const ZERO: C64 = C64 { re: 0.0, im: 0.0 };

    pub fn new(re: f64, im: f64) -> Self {
        Self { re, im }
    }

    pub fn from_polar(r: f64, theta: f64) -> Self {
        Self { re: r * theta.cos(), im: r * theta.sin() }
    }

    pub fn mul(self, o: C64) -> C64 {
        C64::new(self.re * o.re - self.im * o.im, self.re * o.im + self.im * o.re)
    }

    pub fn add(self, o: C64) -> C64 {
        C64::new(self.re + o.re, self.im + o.im)
    }

    pub fn sub(self, o: C64) -> C64 {
        C64::new(self.re - o.re, self.im - o.im)
    }

    pub fn norm_sqr(self) -> f64 {
        self.re * self.re + self.im * self.im
    }

    pub fn scale(self, s: f64) -> C64 {
        C64::new(self.re * s, self.im * s)
    }
}

/// In-place FFT; `inverse` selects the inverse transform (scaled by 1/N).
///
/// # Panics
/// If `x.len()` is not a power of two.
pub fn fft_in_place(x: &mut [C64], inverse: bool) {
    let n = x.len();
    assert!(n.is_power_of_two(), "FFT length must be a power of two, got {n}");
    if n <= 1 {
        return;
    }

    // Bit-reversal permutation.
    let bits = n.trailing_zeros();
    for i in 0..n {
        let j = i.reverse_bits() >> (usize::BITS - bits);
        if i < j {
            x.swap(i, j);
        }
    }

    // Butterflies.
    let sign = if inverse { 1.0 } else { -1.0 };
    let mut len = 2;
    while len <= n {
        let ang = sign * 2.0 * PI / len as f64;
        let wlen = C64::from_polar(1.0, ang);
        for chunk in x.chunks_mut(len) {
            let mut w = C64::new(1.0, 0.0);
            let half = len / 2;
            for i in 0..half {
                let u = chunk[i];
                let v = chunk[i + half].mul(w);
                chunk[i] = u.add(v);
                chunk[i + half] = u.sub(v);
                w = w.mul(wlen);
            }
        }
        len <<= 1;
    }

    if inverse {
        let inv_n = 1.0 / n as f64;
        for v in x.iter_mut() {
            *v = v.scale(inv_n);
        }
    }
}

/// FFT frequencies in cycles/sample, matching `numpy.fft.fftfreq`.
pub fn fftfreq(n: usize) -> Vec<f64> {
    let nf = n as f64;
    (0..n)
        .map(|i| {
            if i <= (n - 1) / 2 {
                i as f64 / nf
            } else {
                i as f64 / nf - 1.0
            }
        })
        .collect()
}

/// Next power of two >= n.
pub fn next_pow2(n: usize) -> usize {
    n.next_power_of_two()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_close(a: C64, b: C64, tol: f64) {
        assert!(
            (a.re - b.re).abs() < tol && (a.im - b.im).abs() < tol,
            "{a:?} != {b:?}"
        );
    }

    #[test]
    fn dc_signal() {
        let mut x = vec![C64::new(1.0, 0.0); 8];
        fft_in_place(&mut x, false);
        assert_close(x[0], C64::new(8.0, 0.0), 1e-12);
        for v in &x[1..] {
            assert_close(*v, C64::ZERO, 1e-12);
        }
    }

    #[test]
    fn single_tone() {
        // x[n] = exp(2*pi*i*k0*n/N) -> delta at bin k0.
        let n = 16;
        let k0 = 3;
        let mut x: Vec<C64> = (0..n)
            .map(|i| C64::from_polar(1.0, 2.0 * PI * k0 as f64 * i as f64 / n as f64))
            .collect();
        fft_in_place(&mut x, false);
        assert_close(x[k0], C64::new(n as f64, 0.0), 1e-9);
        assert_close(x[k0 + 1], C64::ZERO, 1e-9);
    }

    #[test]
    fn roundtrip_random() {
        use crate::channel::mt19937::Mt19937;
        let mut mt = Mt19937::new(9);
        let orig: Vec<C64> =
            (0..256).map(|_| C64::new(mt.next_gaussian(), mt.next_gaussian())).collect();
        let mut x = orig.clone();
        fft_in_place(&mut x, false);
        fft_in_place(&mut x, true);
        for (a, b) in x.iter().zip(&orig) {
            assert_close(*a, *b, 1e-9);
        }
    }

    #[test]
    fn parseval() {
        use crate::channel::mt19937::Mt19937;
        let mut mt = Mt19937::new(10);
        let x: Vec<C64> = (0..128).map(|_| C64::new(mt.next_gaussian(), 0.0)).collect();
        let t: f64 = x.iter().map(|v| v.norm_sqr()).sum();
        let mut f = x.clone();
        fft_in_place(&mut f, false);
        let fsum: f64 = f.iter().map(|v| v.norm_sqr()).sum();
        assert!((t - fsum / 128.0).abs() < 1e-9);
    }

    #[test]
    fn fftfreq_matches_numpy_layout() {
        let f = fftfreq(8);
        assert_eq!(f, vec![0.0, 0.125, 0.25, 0.375, -0.5, -0.375, -0.25, -0.125]);
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn rejects_non_pow2() {
        let mut x = vec![C64::ZERO; 12];
        fft_in_place(&mut x, false);
    }
}
