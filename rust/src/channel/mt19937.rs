//! Mersenne-Twister MT19937 PRNG.
//!
//! The paper (following its reference [18]) drives the transmitter with a
//! Mersenne-Twister pseudo-random pattern to avoid the PRBS-overfitting
//! pitfalls of short LFSR patterns.  This is the reference MT19937 of
//! Matsumoto & Nishimura (the same generator behind numpy's
//! `RandomState`), implemented from the published recurrence.

const N: usize = 624;
const M: usize = 397;
const MATRIX_A: u32 = 0x9908_b0df;
const UPPER_MASK: u32 = 0x8000_0000;
const LOWER_MASK: u32 = 0x7fff_ffff;

/// MT19937 state.
pub struct Mt19937 {
    mt: [u32; N],
    mti: usize,
}

impl Mt19937 {
    /// Seed with the standard `init_genrand` initialization.
    pub fn new(seed: u32) -> Self {
        let mut mt = [0u32; N];
        mt[0] = seed;
        for i in 1..N {
            mt[i] = 1_812_433_253u32
                .wrapping_mul(mt[i - 1] ^ (mt[i - 1] >> 30))
                .wrapping_add(i as u32);
        }
        Self { mt, mti: N }
    }

    /// Next 32 uniform random bits.
    pub fn next_u32(&mut self) -> u32 {
        if self.mti >= N {
            for i in 0..N {
                let y = (self.mt[i] & UPPER_MASK) | (self.mt[(i + 1) % N] & LOWER_MASK);
                let mut next = self.mt[(i + M) % N] ^ (y >> 1);
                if y & 1 != 0 {
                    next ^= MATRIX_A;
                }
                self.mt[i] = next;
            }
            self.mti = 0;
        }
        let mut y = self.mt[self.mti];
        self.mti += 1;
        y ^= y >> 11;
        y ^= (y << 7) & 0x9d2c_5680;
        y ^= (y << 15) & 0xefc6_0000;
        y ^= y >> 18;
        y
    }

    /// Uniform double in [0, 1) with 53-bit resolution (`genrand_res53`).
    pub fn next_f64(&mut self) -> f64 {
        let a = (self.next_u32() >> 5) as f64; // 27 bits
        let b = (self.next_u32() >> 6) as f64; // 26 bits
        (a * 67_108_864.0 + b) / 9_007_199_254_740_992.0
    }

    /// Standard-normal sample via Box-Muller (used for AWGN).
    pub fn next_gaussian(&mut self) -> f64 {
        // Rejection-free polar-less form; u1 in (0,1] to avoid ln(0).
        let u1 = 1.0 - self.next_f64();
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_reference_vectors() {
        // First outputs of MT19937 seeded with 5489 (the canonical seed),
        // from the reference implementation.
        let mut mt = Mt19937::new(5489);
        let expect: [u32; 5] =
            [3_499_211_612, 581_869_302, 3_890_346_734, 3_586_334_585, 545_404_204];
        for e in expect {
            assert_eq!(mt.next_u32(), e);
        }
    }

    #[test]
    fn res53_in_unit_interval() {
        let mut mt = Mt19937::new(1);
        for _ in 0..1000 {
            let v = mt.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut mt = Mt19937::new(42);
        let n = 200_000;
        let samples: Vec<f64> = (0..n).map(|_| mt.next_gaussian()).collect();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Mt19937::new(1);
        let mut b = Mt19937::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u32()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u32()).collect::<Vec<_>>()
        );
    }
}
