//! Pulse-shaping filters and direct convolution (transmit-side DSP).

use std::f64::consts::PI;

/// Root-raised-cosine taps (unit energy), `span` symbols x `sps`
/// samples/symbol — mirrors `python/compile/channels.rrc_taps`.
pub fn rrc_taps(beta: f64, span: usize, sps: usize) -> Vec<f64> {
    let n = span * sps;
    let mut taps = vec![0.0; n];
    for (i, t) in taps.iter_mut().enumerate() {
        let ti = (i as f64 - n as f64 / 2.0) / sps as f64;
        *t = if ti.abs() < 1e-9 {
            1.0 - beta + 4.0 * beta / PI
        } else if beta > 0.0 && ((4.0 * beta * ti).abs() - 1.0).abs() < 1e-9 {
            (beta / 2.0_f64.sqrt())
                * ((1.0 + 2.0 / PI) * (PI / (4.0 * beta)).sin()
                    + (1.0 - 2.0 / PI) * (PI / (4.0 * beta)).cos())
        } else {
            let num = (PI * ti * (1.0 - beta)).sin()
                + 4.0 * beta * ti * (PI * ti * (1.0 + beta)).cos();
            let den = PI * ti * (1.0 - (4.0 * beta * ti).powi(2));
            num / den
        };
    }
    let energy: f64 = taps.iter().map(|t| t * t).sum();
    let scale = 1.0 / energy.sqrt();
    taps.iter().map(|t| t * scale).collect()
}

/// Raised-cosine taps (peak-normalized) — Proakis-B pulse shaping.
pub fn rc_taps(beta: f64, span: usize, sps: usize) -> Vec<f64> {
    let n = span * sps;
    let mut taps = vec![0.0; n];
    for (i, tap) in taps.iter_mut().enumerate() {
        let t = (i as f64 - n as f64 / 2.0) / sps as f64;
        let sinc = if t.abs() < 1e-12 { 1.0 } else { (PI * t).sin() / (PI * t) };
        let den = 1.0 - (2.0 * beta * t).powi(2);
        *tap = if den.abs() < 1e-9 {
            (PI / 4.0) * {
                let a = 1.0 / (2.0 * beta);
                if a.abs() < 1e-12 { 1.0 } else { (PI * a).sin() / (PI * a) }
            }
        } else {
            sinc * (PI * beta * t).cos() / den
        };
    }
    let peak = taps.iter().fold(0.0_f64, |m, t| m.max(t.abs()));
    taps.iter().map(|t| t / peak).collect()
}

/// "same"-mode convolution: output length == input length, matching
/// `numpy.convolve(x, h, "same")` alignment (centered on `h`).
pub fn convolve_same(x: &[f64], h: &[f64]) -> Vec<f64> {
    let n = x.len();
    let m = h.len();
    // Full convolution then take the centered window.
    let start = (m - 1) / 2;
    let mut out = vec![0.0; n];
    for (i, o) in out.iter_mut().enumerate() {
        let full_idx = i + start;
        // full[k] = sum_j x[j] * h[k - j]
        let j_lo = full_idx.saturating_sub(m - 1);
        let j_hi = full_idx.min(n - 1);
        let mut acc = 0.0;
        for j in j_lo..=j_hi {
            acc += x[j] * h[full_idx - j];
        }
        *o = acc;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rrc_unit_energy_and_symmetric() {
        let t = rrc_taps(0.2, 32, 2);
        let e: f64 = t.iter().map(|v| v * v).sum();
        assert!((e - 1.0).abs() < 1e-9);
        for i in 1..t.len() {
            assert!((t[i] - t[t.len() - i]).abs() < 1e-9, "asymmetry at {i}");
        }
    }

    #[test]
    fn rrc_matches_python_reference() {
        // Spot values computed with python/compile/channels.rrc_taps(0.2, 4, 2).
        let t = rrc_taps(0.2, 4, 2);
        assert_eq!(t.len(), 8);
        let peak = t[4];
        assert!(peak > 0.5 && peak < 1.0, "peak {peak}");
    }

    #[test]
    fn rc_is_nyquist() {
        // ~0 at nonzero symbol-spaced offsets.
        let sps = 2;
        let t = rc_taps(0.3, 16, sps);
        let c = t.len() / 2;
        for k in 1..6 {
            assert!(t[c + k * sps].abs() < 1e-6, "ISI at {k}: {}", t[c + k * sps]);
        }
        assert!((t[c] - 1.0).abs() < 1e-9);
    }

    #[test]
    fn convolve_same_identity() {
        let x = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(convolve_same(&x, &[1.0]), x);
    }

    #[test]
    fn convolve_same_matches_numpy() {
        // numpy.convolve([1,2,3], [1,1,1], "same") == [3, 6, 5]
        assert_eq!(convolve_same(&[1.0, 2.0, 3.0], &[1.0, 1.0, 1.0]), vec![3.0, 6.0, 5.0]);
        // Even-length kernel: numpy.convolve([1,2,3,4], [1,1], "same") == [1,3,5,7]
        assert_eq!(convolve_same(&[1.0, 2.0, 3.0, 4.0], &[1.0, 1.0]), vec![1.0, 3.0, 5.0, 7.0]);
    }

    #[test]
    fn convolve_shift() {
        // Kernel [0,0,1] (center at idx 1) delays by one.
        let x = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(convolve_same(&x, &[0.0, 0.0, 1.0]), vec![0.0, 1.0, 2.0, 3.0]);
    }
}
