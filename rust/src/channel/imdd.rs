//! 40 GBd PAM-2 IM/DD optical-fiber channel (Sec. 2.1).
//!
//! The paper captures this channel on an experimental testbed (MZM at
//! quadrature, 31.5 km SSMF, photodiode, real-time scope).  This module
//! rebuilds the same impairment chain synthetically (DESIGN.md §3
//! substitution table): the composite of chromatic dispersion applied to
//! the optical *field* and square-law detection of the *intensity* is a
//! nonlinear channel a linear equalizer cannot invert — the mechanism
//! behind the paper's headline CNN-vs-FIR gap.
//!
//! The chain mirrors `python/compile/channels.imdd` (which generates the
//! training data), so models trained there equalize streams generated
//! here.

use super::awgn::add_awgn;
use super::fft::{fft_in_place, fftfreq, next_pow2, C64};
use super::filter::{convolve_same, rrc_taps};
use super::{normalize, prbs, upsample, Channel, ChannelData, N_OS};
use std::f64::consts::PI;

const C_LIGHT: f64 = 299_792_458.0; // m/s
const LAMBDA: f64 = 1550e-9; // m
const D_CD: f64 = 16e-6; // s/m^2 (16 ps/(nm km))
const BAUD: f64 = 40e9;

/// IM/DD channel parameters.
#[derive(Debug, Clone)]
pub struct ImddChannel {
    /// Fiber length in km (paper: 31.5).
    pub fiber_km: f64,
    /// Receiver SNR in dB measured on the detected signal.
    pub snr_db: f64,
    /// RRC roll-off.
    pub rrc_beta: f64,
    /// RRC span in symbols.
    pub rrc_span: usize,
    /// MZM drive modulation index.
    pub mod_index: f64,
}

impl Default for ImddChannel {
    fn default() -> Self {
        Self { fiber_km: 31.5, snr_db: 25.0, rrc_beta: 0.2, rrc_span: 32, mod_index: 0.7 }
    }
}

impl ImddChannel {
    /// Frequency response of CD over the fiber:
    /// `H(w) = exp(-j * beta2/2 * w^2 * L)` with
    /// `beta2 = -D lambda^2 / (2 pi c)`.
    fn cd_phase(&self, freq_cycles_per_sample: f64, fs: f64) -> f64 {
        let beta2 = -D_CD * LAMBDA * LAMBDA / (2.0 * PI * C_LIGHT);
        let w = 2.0 * PI * freq_cycles_per_sample * fs;
        -0.5 * beta2 * (self.fiber_km * 1e3) * w * w
    }
}

impl Channel for ImddChannel {
    fn transmit(&self, n_sym: usize, seed: u32) -> ChannelData {
        let fs = BAUD * N_OS as f64;
        let symbols = prbs(n_sym, seed);
        let sym_f64: Vec<f64> = symbols.iter().map(|&s| s as f64).collect();

        // TX: upsample -> RRC -> MZM field at quadrature bias.
        let up = upsample(&symbols, N_OS);
        let up_f64: Vec<f64> = up.iter().map(|&v| v as f64).collect();
        let taps = rrc_taps(self.rrc_beta, self.rrc_span, N_OS);
        let drive = convolve_same(&up_f64, &taps);
        let field: Vec<f64> = drive
            .iter()
            .map(|&v| (0.25 * PI * (1.0 - self.mod_index * v.clamp(-1.5, 1.5))).cos())
            .collect();

        // CD all-pass on the field (frequency domain, pow2-padded).
        let n = field.len();
        let nfft = next_pow2(n);
        let mut spec: Vec<C64> = field
            .iter()
            .map(|&v| C64::new(v, 0.0))
            .chain(std::iter::repeat(C64::ZERO))
            .take(nfft)
            .collect();
        fft_in_place(&mut spec, false);
        for (s, f) in spec.iter_mut().zip(fftfreq(nfft)) {
            let phase = self.cd_phase(f, fs);
            *s = s.mul(C64::from_polar(1.0, phase));
        }
        fft_in_place(&mut spec, true);

        // Photodiode: square-law detection of the dispersed field.
        let mut photo: Vec<f64> = spec[..n].iter().map(|c| c.norm_sqr()).collect();
        let mean = photo.iter().sum::<f64>() / photo.len() as f64;
        let var =
            photo.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / photo.len() as f64;
        let std = var.sqrt().max(1e-12);
        for v in photo.iter_mut() {
            *v = (*v - mean) / std;
        }

        add_awgn(&mut photo, self.snr_db, seed.wrapping_add(1));
        let mut rx: Vec<f32> = photo.iter().map(|&v| v as f32).collect();
        normalize(&mut rx);

        ChannelData { rx, symbols: sym_f64.iter().map(|&v| v as f32).collect() }
    }

    fn name(&self) -> &'static str {
        "imdd"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_rate() {
        let d = ImddChannel::default().transmit(4000, 0);
        assert_eq!(d.rx.len(), 4000 * N_OS);
        assert_eq!(d.symbols.len(), 4000);
    }

    #[test]
    fn deterministic() {
        let ch = ImddChannel::default();
        let a = ch.transmit(1000, 3);
        let b = ch.transmit(1000, 3);
        assert_eq!(a.rx, b.rx);
        assert_eq!(a.symbols, b.symbols);
    }

    #[test]
    fn normalized_output() {
        let d = ImddChannel::default().transmit(20_000, 0);
        let n = d.rx.len() as f64;
        let mean = d.rx.iter().map(|&v| v as f64).sum::<f64>() / n;
        let var = d.rx.iter().map(|&v| (v as f64 - mean).powi(2)).sum::<f64>() / n;
        assert!(mean.abs() < 0.05);
        assert!((var - 1.0).abs() < 0.1);
    }

    #[test]
    fn symbol_correlation_present() {
        // Symbol-position samples must carry symbol information.
        let d = ImddChannel::default().transmit(20_000, 0);
        let xs: Vec<f64> = d.rx.iter().step_by(N_OS).map(|&v| v as f64).collect();
        let ys: Vec<f64> = d.symbols.iter().map(|&v| v as f64).collect();
        let c = corr(&xs, &ys);
        assert!(c.abs() > 0.3, "decorrelated: {c}");
    }

    #[test]
    fn dispersion_increases_isi() {
        let near = ImddChannel { fiber_km: 1.0, snr_db: 40.0, ..Default::default() };
        let far = ImddChannel { fiber_km: 31.5, snr_db: 40.0, ..Default::default() };
        let dn = near.transmit(20_000, 0);
        let df = far.transmit(20_000, 0);
        let cn = corr(
            &dn.rx.iter().step_by(2).map(|&v| v as f64).collect::<Vec<_>>(),
            &dn.symbols.iter().map(|&v| v as f64).collect::<Vec<_>>(),
        );
        let cf = corr(
            &df.rx.iter().step_by(2).map(|&v| v as f64).collect::<Vec<_>>(),
            &df.symbols.iter().map(|&v| v as f64).collect::<Vec<_>>(),
        );
        assert!(cf.abs() < cn.abs(), "CD did not spread energy: {cn} vs {cf}");
    }

    fn corr(a: &[f64], b: &[f64]) -> f64 {
        let n = a.len() as f64;
        let ma = a.iter().sum::<f64>() / n;
        let mb = b.iter().sum::<f64>() / n;
        let cov: f64 = a.iter().zip(b).map(|(x, y)| (x - ma) * (y - mb)).sum::<f64>() / n;
        let sa = (a.iter().map(|x| (x - ma).powi(2)).sum::<f64>() / n).sqrt();
        let sb = (b.iter().map(|y| (y - mb).powi(2)).sum::<f64>() / n).sqrt();
        cov / (sa * sb)
    }
}
