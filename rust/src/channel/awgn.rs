//! Additive white Gaussian noise at a target SNR.

use super::mt19937::Mt19937;

/// Add AWGN so the resulting SNR (signal power / noise power) is
/// `snr_db`, measured against the *current* signal power.
pub fn add_awgn(x: &mut [f64], snr_db: f64, seed: u32) {
    let n = x.len() as f64;
    let sig_pow = x.iter().map(|v| v * v).sum::<f64>() / n;
    let noise_std = (sig_pow / 10f64.powf(snr_db / 10.0)).sqrt();
    let mut mt = Mt19937::new(seed);
    for v in x.iter_mut() {
        *v += noise_std * mt.next_gaussian();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snr_is_respected() {
        let clean: Vec<f64> = (0..100_000).map(|i| (i as f64 * 0.1).sin()).collect();
        for snr in [0.0, 10.0, 20.0] {
            let mut noisy = clean.clone();
            add_awgn(&mut noisy, snr, 3);
            let noise_pow: f64 = noisy
                .iter()
                .zip(&clean)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f64>()
                / clean.len() as f64;
            let sig_pow: f64 =
                clean.iter().map(|v| v * v).sum::<f64>() / clean.len() as f64;
            let measured = 10.0 * (sig_pow / noise_pow).log10();
            assert!((measured - snr).abs() < 0.2, "snr {snr} measured {measured}");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = vec![1.0; 64];
        let mut b = vec![1.0; 64];
        add_awgn(&mut a, 10.0, 5);
        add_awgn(&mut b, 10.0, 5);
        assert_eq!(a, b);
    }
}
