//! L3 hot-path benchmarks (§Perf): the native fixed-point datapath
//! (alloc-per-call vs scratch-reusing vs fake-quant vs the integer
//! fast path), the full coordinator pipeline in all three execution
//! modes (sequential / per-chunk threads / chunk-batched threads)
//! across instance counts, the stream-partitioning bookkeeping in
//! isolation, and the channel simulators.  With `--features pjrt` (and
//! a real `xla` crate) the PJRT executable paths are measured too.
//!
//! Headline numbers: `pipeline_batch n_i=4` vs `pipeline_seq n_i=1`
//! (the Sec. 5.3 parallelism claim) and `native_cnn_int16` vs
//! `native_cnn_fakequant` (the Sec. 4 quantized arithmetic claim) on
//! the native backend.
//!
//! Pass `--quick` (CI perf smoke) for reduced budgets and workloads;
//! the int16/f32 bit-identity gate is asserted in every mode before
//! anything is timed.

use equalizer::channel::{imdd::ImddChannel, proakis::ProakisBChannel, Channel};
use equalizer::coordinator::instance::AnyInstance;
use equalizer::coordinator::pipeline::EqualizerPipeline;
use equalizer::coordinator::{msm, ogm, ssm};
use equalizer::equalizer::cnn::{CnnScratch, FixedPointCnn};
use equalizer::equalizer::weights::{CnnTopologyCfg, CnnWeights};
use equalizer::fixedpoint::QuantSpec;
use equalizer::runtime::ArtifactRegistry;
use equalizer::util::bench::{header, Bencher, Throughput};

fn artifacts_dir() -> String {
    format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let b = if quick { Bencher::quick() } else { Bencher::default() };
    let cfg = CnnTopologyCfg::SELECTED;
    let stream_exp = if quick { 15 } else { 17 };

    // ---- channel simulators (substrate cost) -------------------------
    header("channel simulators (64k symbols)");
    let imdd = ImddChannel::default();
    let m_imdd = b.bench("imdd_transmit_64k", || imdd.transmit(65_536, 1));
    println!("    -> {}", Throughput::from_measurement(&m_imdd, 65_536.0).line());
    let pro = ProakisBChannel::default();
    b.bench("proakis_transmit_64k", || pro.transmit(65_536, 1));

    // ---- stream partitioning bookkeeping alone ------------------------
    header("coordinator bookkeeping (no compute)");
    let data = imdd.transmit(1 << stream_exp, 2);
    b.bench("ogm_make_chunks l_inst=888 o=68", || ogm::make_chunks(&data.rx, 888, 68));
    let chunks = ogm::make_chunks(&data.rx, 888, 68);
    b.bench("ssm_distribute n_i=64", || ssm::distribute(&chunks, 64));
    let queues = ssm::distribute(&chunks, 64);
    let fake_outs: Vec<Vec<Vec<f32>>> =
        queues.iter().map(|q| q.iter().map(|_| vec![0.0f32; 512]).collect()).collect();
    b.bench("msm_collect n_i=64", || msm::collect(&fake_outs, chunks.len()));

    // ---- native fixed-point datapath ----------------------------------
    let weights_path = format!("{}/weights_cnn_imdd.json", artifacts_dir());
    let Ok(weights) = CnnWeights::load(&weights_path) else {
        println!("\n(native weights missing; datapath + pipeline benches skipped)");
        return;
    };
    header("native datapath (1024-sample chunk)");
    let x: Vec<f32> = (0..1024).map(|i| (i as f32 * 0.1).sin()).collect();
    let syms = cfg.out_symbols(1024) as f64;
    let float_cnn = FixedPointCnn::new(weights.clone(), None);
    let mm = b.bench("native_cnn_f32", || float_cnn.forward(&x));
    println!("    -> {}", Throughput::from_measurement(&mm, syms).line());
    let mut scratch = CnnScratch::default();
    let ms = b.bench("native_cnn_f32_scratch", || float_cnn.forward_with(&x, &mut scratch));
    println!("    -> {}", Throughput::from_measurement(&ms, syms).line());

    let q_cnn = FixedPointCnn::new(weights.clone(), Some(QuantSpec::paper_default(cfg.layers)));
    // Bit-identity gate (also run under --quick in CI): the integer
    // fast path must return exactly what the fake-quant f32 reference
    // computes, on every width the blocking treats differently.
    assert!(q_cnn.uses_integer_path(), "paper formats must pass the provability gate");
    for n in [256usize, 1024, 4096] {
        let xw: Vec<f32> = (0..n).map(|i| (i as f32 * 0.1).sin()).collect();
        assert_eq!(
            q_cnn.forward(&xw),
            q_cnn.forward_reference(&xw),
            "int16 != fakequant_f32 at width {n}"
        );
    }
    println!("(bit-identity: int16 == fakequant_f32 at widths 256/1024/4096)");
    let mq = b.bench("native_cnn_fakequant", || q_cnn.forward_reference_with(&x, &mut scratch));
    let t_ref = Throughput::from_measurement(&mq, syms);
    println!("    -> {}", t_ref.line());
    let mi = b.bench("native_cnn_int16", || q_cnn.forward_with(&x, &mut scratch));
    let t_int = Throughput::from_measurement(&mi, syms);
    println!("    -> {}", t_int.line());
    println!(
        "\nnative_cnn_int16 is {:.2}x vs native_cnn_fakequant (Sec. 4 integer arithmetic)",
        t_int.symbols_per_s / t_ref.symbols_per_s
    );

    // ---- full pipeline: sequential vs threads vs chunk-batched --------
    let Ok(reg) = ArtifactRegistry::discover(artifacts_dir()) else {
        println!("\n(artifact registry unavailable; pipeline benches skipped)");
        return;
    };
    let entry = reg.best_model("cnn", "imdd", 4096).expect("4096 bucket").clone();
    let o_act = cfg.o_act_samples();
    let l_inst = entry.width() - 2 * o_act;
    let data = imdd.transmit(1 << stream_exp, 3);
    let syms_total = (data.rx.len() / 2) as f64;

    header(&format!(
        "full pipeline, {}k symbols (bucket 4096, native backend)",
        1 << (stream_exp - 10)
    ));
    let mut seq_mean = None;
    for n_i in [1usize, 2, 4, 8] {
        let workers: Vec<AnyInstance> =
            (0..n_i).map(|_| AnyInstance::load(&entry).unwrap()).collect();
        let mut pipe = EqualizerPipeline::new(workers, l_inst, o_act, cfg.n_os).unwrap();
        let m = b.bench(&format!("pipeline_seq n_i={n_i}"), || pipe.equalize(&data.rx).unwrap());
        println!("    -> {}", Throughput::from_measurement(&m, syms_total).line());
        if n_i == 1 {
            seq_mean = Some(m.mean);
        }
    }
    for n_i in [1usize, 2, 4, 8] {
        let workers: Vec<AnyInstance> =
            (0..n_i).map(|_| AnyInstance::load(&entry).unwrap()).collect();
        let mut pipe = EqualizerPipeline::new(workers, l_inst, o_act, cfg.n_os).unwrap();
        let m = b.bench(&format!("pipeline_threads n_i={n_i}"), || {
            pipe.equalize_parallel(&data.rx).unwrap()
        });
        println!("    -> {}", Throughput::from_measurement(&m, syms_total).line());
    }
    let mut batch4_mean = None;
    for n_i in [1usize, 2, 4, 8] {
        let workers: Vec<AnyInstance> =
            (0..n_i).map(|_| AnyInstance::load(&entry).unwrap()).collect();
        let mut pipe = EqualizerPipeline::new(workers, l_inst, o_act, cfg.n_os).unwrap();
        let m = b.bench(&format!("pipeline_batch n_i={n_i}"), || {
            pipe.equalize_batch(&data.rx).unwrap()
        });
        println!("    -> {}", Throughput::from_measurement(&m, syms_total).line());
        if n_i == 4 {
            batch4_mean = Some(m.mean);
        }
    }
    if let (Some(seq), Some(batch4)) = (seq_mean, batch4_mean) {
        println!(
            "\npipeline_batch n_i=4 is {:.2}x vs sequential n_i=1 \
             (Sec. 5.3 parallelism on the native backend)",
            seq.as_secs_f64() / batch4.as_secs_f64()
        );
    }

    // ---- quantized profile through the pipeline (integer fast path) ---
    header("full pipeline, quantized profile (int16 datapath)");
    if let Ok(qentry) = reg.exact("cnn_imdd_quant_w4096") {
        for n_i in [1usize, 4] {
            let workers: Vec<AnyInstance> =
                (0..n_i).map(|_| AnyInstance::load(qentry).unwrap()).collect();
            let mut pipe = EqualizerPipeline::new(workers, l_inst, o_act, cfg.n_os).unwrap();
            let m = b.bench(&format!("pipeline_batch_quant n_i={n_i}"), || {
                pipe.equalize_batch(&data.rx).unwrap()
            });
            println!("    -> {}", Throughput::from_measurement(&m, syms_total).line());
        }
    }

    // ---- PJRT execution (needs real xla + HLO artifacts) --------------
    #[cfg(feature = "pjrt")]
    pjrt_benches(&b, &reg);
}

#[cfg(feature = "pjrt")]
fn pjrt_benches(b: &Bencher, reg: &ArtifactRegistry) {
    use equalizer::runtime::{ArtifactKind, Engine};
    if !reg.models.iter().any(|m| m.kind == ArtifactKind::Hlo) {
        println!("\n(no HLO artifacts; PJRT benches skipped)");
        return;
    }
    let engine = match Engine::cpu() {
        Ok(e) => e,
        Err(e) => {
            println!("\n(PJRT unavailable: {e})");
            return;
        }
    };
    header("PJRT executable (per chunk)");
    for width in reg.buckets("cnn", "imdd", false) {
        let model = engine.load(reg.best_model("cnn", "imdd", width).unwrap()).unwrap();
        let x = vec![0.3f32; width];
        let m = b.bench(&format!("pjrt_cnn w={width}"), || model.run_f32(&x).unwrap());
        println!("    -> {}", Throughput::from_measurement(&m, width as f64 / 2.0).line());
    }
}
