//! L3 hot-path benchmarks (§Perf): PJRT execution per width bucket, the
//! full coordinator pipeline (sequential vs per-instance threads), the
//! native fixed-point datapath, the stream-partitioning bookkeeping in
//! isolation, and the channel simulators.  EXPERIMENTS.md §Perf records
//! the before/after of each optimization against these numbers.

use equalizer::channel::{imdd::ImddChannel, proakis::ProakisBChannel, Channel};
use equalizer::coordinator::instance::{PjrtInstance, SharedPjrtInstance};
use equalizer::coordinator::pipeline::EqualizerPipeline;
use equalizer::coordinator::{msm, ogm, ssm};
use equalizer::equalizer::cnn::FixedPointCnn;
use equalizer::equalizer::weights::{CnnTopologyCfg, CnnWeights};
use equalizer::fixedpoint::QuantSpec;
use equalizer::runtime::{ArtifactRegistry, Engine};
use equalizer::util::bench::{header, Bencher};

fn main() {
    let b = Bencher::default();
    let cfg = CnnTopologyCfg::SELECTED;

    // ---- channel simulators (substrate cost) -------------------------
    header("channel simulators (64k symbols)");
    let imdd = ImddChannel::default();
    let m_imdd = b.bench("imdd_transmit_64k", || imdd.transmit(65_536, 1));
    println!("    -> {:.2} Msym/s", m_imdd.throughput(65_536.0) / 1e6);
    let pro = ProakisBChannel::default();
    b.bench("proakis_transmit_64k", || pro.transmit(65_536, 1));

    // ---- stream partitioning bookkeeping alone ------------------------
    header("coordinator bookkeeping (no compute)");
    let data = imdd.transmit(1 << 17, 2);
    b.bench("ogm_make_chunks l_inst=888 o=68", || {
        ogm::make_chunks(&data.rx, 888, 68)
    });
    let chunks = ogm::make_chunks(&data.rx, 888, 68);
    b.bench("ssm_distribute n_i=64", || ssm::distribute(&chunks, 64));
    let queues = ssm::distribute(&chunks, 64);
    let fake_outs: Vec<Vec<Vec<f32>>> =
        queues.iter().map(|q| q.iter().map(|_| vec![0.0f32; 512]).collect()).collect();
    b.bench("msm_collect n_i=64", || msm::collect(&fake_outs, chunks.len()));

    // ---- native fixed-point datapath ----------------------------------
    let weights_path = format!("{}/artifacts/weights_cnn_imdd.json", env!("CARGO_MANIFEST_DIR"));
    if let Ok(weights) = CnnWeights::load(&weights_path) {
        header("native datapath (1024-sample chunk)");
        let x: Vec<f32> = (0..1024).map(|i| (i as f32 * 0.1).sin()).collect();
        let float_cnn = FixedPointCnn::new(weights.clone(), None);
        let mm = b.bench("native_cnn_f32", || float_cnn.forward(&x));
        println!("    -> {:.2} Msym/s", mm.throughput(512.0) / 1e6);
        let q_cnn = FixedPointCnn::new(weights, Some(QuantSpec::paper_default(cfg.layers)));
        b.bench("native_cnn_quantized", || q_cnn.forward(&x));
    }

    // ---- PJRT execution per bucket ------------------------------------
    let art_dir = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));
    let Ok(reg) = ArtifactRegistry::discover(&art_dir) else {
        println!("\n(artifacts not built; PJRT benches skipped)");
        return;
    };
    let engine = Engine::cpu().expect("PJRT");
    header("PJRT executable (per chunk)");
    for width in reg.buckets("cnn", "imdd", false) {
        let model = engine.load(reg.best_model("cnn", "imdd", width).unwrap()).unwrap();
        let x = vec![0.3f32; width];
        let m = b.bench(&format!("pjrt_cnn w={width}"), || model.run_f32(&x).unwrap());
        println!("    -> {:.2} Msym/s", m.throughput(width as f64 / 2.0) / 1e6);
    }
    if let Ok(e) = reg.exact("cnn_imdd_w1024_b8") {
        let model = engine.load(e).unwrap();
        let x = vec![0.3f32; 8 * 1024];
        let m = b.bench("pjrt_cnn w=1024 batch=8", || model.run_f32(&x).unwrap());
        println!("    -> {:.2} Msym/s", m.throughput(8.0 * 512.0) / 1e6);
    }
    if let Ok(e) = reg.exact("cnn_imdd_quant_w1024") {
        let model = engine.load(e).unwrap();
        let x = vec![0.3f32; 1024];
        b.bench("pjrt_cnn_quant w=1024", || model.run_f32(&x).unwrap());
    }

    // ---- full pipeline: sequential vs threaded ------------------------
    header("full pipeline, 128k symbols (bucket 4096)");
    let data = imdd.transmit(1 << 17, 3);
    let o_act = cfg.o_act_samples();
    for n_i in [1usize, 2, 4, 8] {
        let entry = reg.best_model("cnn", "imdd", 4096).unwrap();
        let l_inst = entry.width() - 2 * o_act;
        let workers: Vec<PjrtInstance> =
            (0..n_i).map(|_| PjrtInstance::load(entry).unwrap()).collect();
        let mut pipe = EqualizerPipeline::new(workers, l_inst, o_act, cfg.n_os).unwrap();
        let m = b.bench(&format!("pipeline_threads(own client) n_i={n_i}"), || {
            pipe.equalize_parallel(&data.rx).unwrap()
        });
        println!("    -> {:.2} Msym/s", m.throughput((data.rx.len() / 2) as f64) / 1e6);
    }
    // §Perf optimization: N instances sharing ONE PJRT client, run
    // sequentially — the client's internal thread pool supplies the
    // parallelism without client-per-instance oversubscription.
    for n_i in [1usize, 4] {
        let entry = reg.best_model("cnn", "imdd", 4096).unwrap();
        let l_inst = entry.width() - 2 * o_act;
        let workers: Vec<SharedPjrtInstance> =
            (0..n_i).map(|_| SharedPjrtInstance::load(&engine, entry).unwrap()).collect();
        let mut pipe = EqualizerPipeline::new(workers, l_inst, o_act, cfg.n_os).unwrap();
        let m = b.bench(&format!("pipeline_shared_client n_i={n_i}"), || {
            pipe.equalize(&data.rx).unwrap()
        });
        println!("    -> {:.2} Msym/s", m.throughput((data.rx.len() / 2) as f64) / 1e6);
    }
}
