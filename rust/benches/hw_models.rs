//! Bench + figures: FPGA resource/power models (regenerates Table 1 and
//! Figs. 8a/8b), plus an instance-count ablation on the XCVU13P.

use equalizer::equalizer::weights::CnnTopologyCfg;
use equalizer::hw::device::{XC7S25, XCVU13P};
use equalizer::hw::dop::Dop;
use equalizer::hw::power::{ht_power_w, lp_power_w, lp_throughput_baud};
use equalizer::hw::resource::{ht_design, lp_design, mac_sym_max};
use equalizer::util::bench::{header, Bencher};

fn main() {
    let cfg = CnnTopologyCfg::SELECTED;

    println!("=== Table 1: XCVU13P utilization, 64 instances ===");
    let u = ht_design(&cfg, 64);
    let pct = u.utilization(&XCVU13P);
    println!("resource   modeled          (%)    paper          (%)");
    println!("LUT        {:>9}  {:>8.2}    1176156   68.06", u.luts, pct.lut_pct);
    println!("FF         {:>9}  {:>8.2}    1050179   30.39", u.ffs, pct.ff_pct);
    println!("DSP        {:>9}  {:>8.2}       9648   78.52", u.dsps, pct.dsp_pct);
    println!("BRAM       {:>9}  {:>8.2}       2118   78.79", u.brams, pct.bram_pct);
    println!(
        "MAC_sym ceiling @40GBd: {:.1}  (selected model: {:.2})",
        mac_sym_max(&XCVU13P, 40e9),
        cfg.mac_per_symbol()
    );

    println!("\n=== ablation: utilization vs instance count ===");
    println!("{:>6} {:>8} {:>8} {:>8} {:>8} {:>6}", "N_i", "LUT%", "FF%", "DSP%", "BRAM%", "fits");
    for n_i in [8u64, 16, 32, 64, 96, 128] {
        let u = ht_design(&cfg, n_i);
        let p = u.utilization(&XCVU13P);
        println!(
            "{:>6} {:>8.1} {:>8.1} {:>8.1} {:>8.1} {:>6}",
            n_i,
            p.lut_pct,
            p.ff_pct,
            p.dsp_pct,
            p.bram_pct,
            u.fits(&XCVU13P)
        );
    }

    println!("\n=== Fig. 8a: resource utilization vs DOP (XC7S25) ===");
    println!("{:>6} {:>8} {:>8} {:>8} {:>8}", "DOP", "LUT%", "FF%", "DSP%", "BRAM%");
    for dop in Dop::paper_sweep(&cfg) {
        let u = lp_design(&cfg, dop, &XC7S25).utilization(&XC7S25);
        println!(
            "{:>6} {:>8.1} {:>8.1} {:>8.1} {:>8.1}",
            dop.total(),
            u.lut_pct,
            u.ff_pct,
            u.dsp_pct,
            u.bram_pct
        );
    }

    println!("\n=== Fig. 8b: power + throughput vs DOP (XC7S25) ===");
    println!("{:>6} {:>12} {:>10}", "DOP", "Tput Mbit/s", "Power W");
    for dop in Dop::paper_sweep(&cfg) {
        println!(
            "{:>6} {:>12.1} {:>10.3}",
            dop.total(),
            lp_throughput_baud(&cfg, dop, &XC7S25) / 1e6,
            lp_power_w(&cfg, dop, &XC7S25)
        );
    }
    println!("(paper: 4-110 Mbit/s, 0.1-0.2 W)");
    println!("\nHT power (64 inst): {:.1} W", ht_power_w(&cfg, 64, &XCVU13P));

    header("model evaluation cost");
    let b = Bencher::default();
    b.bench("ht_design(64)", || ht_design(&cfg, 64));
    b.bench("lp_design sweep (5 DOPs)", || {
        Dop::paper_sweep(&cfg)
            .into_iter()
            .map(|d| lp_design(&cfg, d, &XC7S25))
            .collect::<Vec<_>>()
    });
}
