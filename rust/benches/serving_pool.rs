//! Serving-layer benchmarks (§Perf): dispatcher overhead with trivial
//! instances (pure pool bookkeeping), shard scaling on the real native
//! CNN profile — the multi-stream analogue of the `pipeline_hotpath`
//! parallelism headline — and the adaptive-scheduler headline: cross-
//! request coalescing on a 64-client small-burst mix, the regime where
//! per-request execution leaves the datapath mostly idle (the paper's
//! small-batch collapse, Sec. 7, re-created and then closed in
//! software), with group fusion on top — one im2col + GEMM invocation
//! per instance per drained group instead of one per chunk — plus the
//! overload story: an open-loop 2x-capacity trace
//! with admission control off vs on, showing the bounded-queue latency
//! blowup turn into shed rate with the admitted p99 held near budget.

use equalizer::coordinator::instance::DecimatorInstance;
use equalizer::coordinator::pool::{PoolConfig, RoutePolicy, ServerPool, Shard};
use equalizer::coordinator::sched::SchedulerConfig;
use equalizer::coordinator::seqlen::SeqLenOptimizer;
use equalizer::coordinator::server::EqualizerServer;
use equalizer::coordinator::timing::TimingModel;
use equalizer::runtime::ArtifactRegistry;
use equalizer::util::bench::{header, Bencher, Throughput};
use std::time::Duration;

fn decimator_shard(n_i: usize, width: usize, o_act: usize) -> Shard<DecimatorInstance> {
    let instances: Vec<DecimatorInstance> =
        (0..n_i).map(|_| DecimatorInstance { width, n_os: 2 }).collect();
    let opt = SeqLenOptimizer::new(TimingModel::new(64, 8, 3, 9, 200e6));
    let targets: Vec<f64> = (1..=100).map(|i| i as f64 * 1e9).collect();
    Shard::single("default", EqualizerServer::new(instances, o_act, 2, &opt, &targets).unwrap())
}

fn main() {
    let b = Bencher::quick();

    // ---- dispatch overhead: near-free compute, 64 bursts in flight --
    header("pool dispatch (decimator instances, 64 x 8k-sample bursts)");
    let burst: Vec<f32> = (0..8192).map(|i| i as f32).collect();
    for shards in [1usize, 2, 4] {
        let pool = ServerPool::new(
            (0..shards).map(|_| decimator_shard(2, 4096, 64)).collect(),
            RoutePolicy::ShortestQueue,
            64,
        )
        .unwrap()
        .spawn();
        let m = b.bench(&format!("pool_decimator shards={shards}"), || {
            let pending: Vec<_> =
                (0..64).map(|_| pool.submit("default", burst.clone(), None).unwrap()).collect();
            for rx in pending {
                rx.recv().unwrap();
            }
        });
        println!("    -> {:.2} Mreq/s dispatch", m.throughput(64.0) / 1e6);
        pool.shutdown();
    }

    // ---- net front end: loopback TCP vs in-process --------------------
    // Same near-free decimator compute, one burst at a time, in process
    // vs through the full wire path (frame codec + loopback TCP + the
    // server's reader thread).  The gap is the per-request cost of
    // having an outside — docs/PROTOCOL.md documents the frame format,
    // docs/OPERATIONS.md what to expect of it under load.
    header("net front end (loopback TCP vs in-process, 8k-sample bursts)");
    {
        use equalizer::coordinator::net::{NetClient, NetServer};
        let pool = ServerPool::new(
            vec![decimator_shard(2, 4096, 64)],
            RoutePolicy::ShortestQueue,
            64,
        )
        .unwrap()
        .spawn();
        let m = b.bench("net_inprocess call", || {
            pool.call("default", burst.clone(), None).unwrap();
        });
        let local = m.throughput(1.0);
        println!("    -> {:.1} kreq/s in-process", local / 1e3);
        let server = NetServer::spawn(pool.client(), "127.0.0.1:0").unwrap();
        let net = NetClient::connect(server.local_addr()).unwrap();
        let m = b.bench("net_loopback call", || {
            net.call("default", burst.clone(), None).unwrap();
        });
        let remote = m.throughput(1.0);
        println!(
            "    -> {:.1} kreq/s over loopback ({:.2}x in-process: wire + frame codec)",
            remote / 1e3,
            remote / local
        );
        drop(net);
        server.shutdown();
        pool.shutdown();
    }

    // ---- shard scaling on the real native CNN profile ---------------
    let dir = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));
    let Ok(reg) = ArtifactRegistry::discover(dir) else {
        println!("\n(native weights missing; cnn pool benches skipped)");
        return;
    };
    header("pool serving (8 x 16k-sample bursts per profile)");
    let data: Vec<f32> = (0..16_384).map(|i| (i as f32 * 0.17).sin()).collect();
    let symbols = 8.0 * data.len() as f64 / 2.0;
    // cnn_imdd runs the f32 datapath, cnn_imdd_quant the integer fast
    // path — same pool machinery, so the delta is pure datapath.
    'profiles: for profile in ["cnn_imdd", "cnn_imdd_quant"] {
        for shards in [1usize, 2] {
            let cfg = PoolConfig {
                shards,
                instances_per_shard: 2,
                policy: RoutePolicy::ShortestQueue,
                ..PoolConfig::default()
            };
            let pool = match ServerPool::from_registry(&reg, &[profile], &cfg) {
                Ok(p) => p.spawn(),
                Err(e) => {
                    println!("({profile} profile unavailable: {e})");
                    continue 'profiles;
                }
            };
            let m = b.bench(&format!("pool_{profile} shards={shards}"), || {
                let pending: Vec<_> =
                    (0..8).map(|_| pool.submit(profile, data.clone(), None).unwrap()).collect();
                for rx in pending {
                    rx.recv().unwrap();
                }
            });
            println!("    -> {}", Throughput::from_measurement(&m, symbols).line());
            pool.shutdown();
        }
    }

    // ---- coalescing on the small-burst mix (the scheduler headline) --
    // 64 concurrent clients x 128-symbol bursts on the int16 fast
    // path: per-request execution pays one dispatch + one mostly-empty
    // pipeline pass per burst; coalescing batches the queue into a few
    // passes that keep every instance busy.  Bit-exactness of the two
    // modes is asserted in tests/adaptive_sched.rs — this target only
    // measures the throughput gap.
    header("pool coalescing (64 clients x 128-symbol bursts, cnn_imdd_quant)");
    let clients = 64usize;
    let burst: Vec<f32> = (0..256).map(|i| (i as f32 * 0.19).sin()).collect();
    let small_symbols = (clients * burst.len() / 2) as f64;
    let mut rates = Vec::new();
    let coalesced = SchedulerConfig::default().with_coalescing(Duration::from_millis(1));
    // per-request stays at rates[0] and coalesced at rates[1]: the
    // ratio print below and the open-loop offered-load estimate index
    // by position.
    let modes = [
        ("per-request", SchedulerConfig::default()),
        ("coalesced", coalesced.clone()),
        ("group-fused", coalesced.with_group_fusion()),
    ];
    for (name, scheduler) in modes {
        let cfg = PoolConfig {
            shards: 2,
            instances_per_shard: 4,
            policy: RoutePolicy::ShortestQueue,
            queue_cap: clients,
            scheduler,
            ..PoolConfig::default()
        };
        let pool = match ServerPool::from_registry(&reg, &["cnn_imdd_quant"], &cfg) {
            Ok(p) => p.spawn(),
            Err(e) => {
                println!("(cnn_imdd_quant profile unavailable: {e})");
                return;
            }
        };
        let m = b.bench(&format!("pool_smallburst {name}"), || {
            let pending: Vec<_> = (0..clients)
                .map(|_| pool.submit("cnn_imdd_quant", burst.clone(), None).unwrap())
                .collect();
            for rx in pending {
                rx.recv().unwrap();
            }
        });
        let t = Throughput::from_measurement(&m, small_symbols);
        println!("    -> {}", t.line());
        rates.push(t.symbols_per_s);
        let stats = pool.shutdown();
        println!(
            "       ({} of {} requests served coalesced, {} kernel invocations)",
            stats.total_coalesced_requests(),
            stats.total_requests(),
            stats.total_kernel_invocations()
        );
    }
    println!(
        "\ncoalescing is {:.2}x per-request execution on the small-burst mix",
        rates[1] / rates[0]
    );
    println!(
        "group fusion (one im2col+GEMM per instance per drained group) is {:.2}x \
         per-chunk coalesced dispatch",
        rates[2] / rates[1]
    );

    // ---- latency SLO: fixed window vs adaptive window ---------------
    // Same small-burst mix, third way: the 1 ms window *under a p99
    // budget*.  The SLO loop shrinks each shard's window until the
    // measured end-to-end p99 fits the budget — batching then comes
    // only from draining what is already queued, so throughput stays
    // close to the fixed-window run while the window-induced tail
    // disappears.  `repro bench --json` records the same comparison as
    // `serving_slo_*` rows (with p50/p99) in BENCH_pr5.json.
    header("pool latency SLO (64 clients x 128-symbol bursts, p99 budget 400 us)");
    use equalizer::coordinator::sched::LatencySlo;
    use equalizer::metrics::stats::LatencyStats;
    let fixed = SchedulerConfig::default().with_coalescing(Duration::from_millis(1));
    let slo_modes = [
        ("fixed-window", fixed.clone()),
        ("slo-adaptive", fixed.with_slo(LatencySlo::new(400.0))),
    ];
    for (name, scheduler) in slo_modes {
        let cfg = PoolConfig {
            shards: 2,
            instances_per_shard: 4,
            policy: RoutePolicy::ShortestQueue,
            queue_cap: clients,
            scheduler,
            ..PoolConfig::default()
        };
        let pool = match ServerPool::from_registry(&reg, &["cnn_imdd_quant"], &cfg) {
            Ok(p) => p.spawn(),
            Err(e) => {
                println!("(cnn_imdd_quant profile unavailable: {e})");
                return;
            }
        };
        let mut lat = LatencyStats::new();
        let mut total_symbols = 0usize;
        let t0 = std::time::Instant::now();
        for _ in 0..16 {
            let pending: Vec<_> = (0..clients)
                .map(|_| pool.submit("cnn_imdd_quant", burst.clone(), None).unwrap())
                .collect();
            for rx in pending {
                let resp = rx.recv().unwrap();
                lat.record_us(resp.latency_us);
                total_symbols += resp.soft_symbols.len();
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let t = Throughput::from_rate(total_symbols as f64, wall);
        println!(
            "pool_slo {name:35} {}  p50 {:.1} us  p99 {:.1} us",
            t.line(),
            lat.percentile_us(50.0),
            lat.percentile_us(99.0)
        );
        let stats = pool.shutdown();
        let windows: Vec<String> =
            stats.shards.iter().map(|s| format!("{:.0}", s.window_us)).collect();
        println!("       (final per-shard windows: {} us)", windows.join(" / "));
    }

    // ---- admission control: open-loop 2x overload, off vs on --------
    // The closed-loop runs above measure clients that wait their turn;
    // an open-loop trace keeps offering work at 2x the measured
    // coalesced capacity no matter how the pool copes.  Without
    // admission the bounded queue absorbs the excess as latency (p99
    // climbs toward queue_cap x service time, then Full rejections);
    // with it the backlog estimator deadline-rejects at the
    // margin x budget line, so the excess shows up as shed rate while
    // the admitted p99 stays near the budget.  `repro bench --json`
    // records the same sweep as `serving_open_loop_*` rows in
    // BENCH_pr6.json.
    header("pool admission (open-loop 2x overload, cnn_imdd_quant, p99 budget 2 ms)");
    use equalizer::coordinator::pool::TrySubmit;
    use equalizer::coordinator::sched::AdmissionConfig;
    use equalizer::util::loadgen::OpenLoopSpec;
    let coalesced_rps = rates[1] / (burst.len() as f64 / 2.0);
    let offered = 2.0 * coalesced_rps;
    let budget_us = 2_000.0;
    let window = SchedulerConfig::default().with_coalescing(Duration::from_millis(1));
    let adm_modes = [
        ("admission-off", window.clone()),
        ("admission-on", window.with_admission(AdmissionConfig::new(LatencySlo::new(budget_us)))),
    ];
    for (name, scheduler) in adm_modes {
        let cfg = PoolConfig {
            shards: 2,
            instances_per_shard: 4,
            policy: RoutePolicy::ShortestQueue,
            queue_cap: clients,
            scheduler,
            ..PoolConfig::default()
        };
        let pool = ServerPool::from_registry(&reg, &["cnn_imdd_quant"], &cfg).unwrap().spawn();
        // Seed the service-time EWMA so the estimator is live from the
        // first arrival.
        pool.call("cnn_imdd_quant", burst.clone(), None).unwrap();
        let trace = OpenLoopSpec::poisson("cnn_imdd_quant", offered, Duration::from_millis(500))
            .schedule()
            .unwrap();
        let client = pool.client();
        let t0 = std::time::Instant::now();
        let mut pending = Vec::new();
        let (mut shed, mut full) = (0u64, 0u64);
        for a in &trace {
            while t0.elapsed() < a.at {
                std::thread::yield_now();
            }
            match client.try_submit("cnn_imdd_quant", burst.clone(), None).unwrap() {
                TrySubmit::Queued(rx) => pending.push(rx),
                TrySubmit::Shed(_) => shed += 1,
                TrySubmit::Full(_) => full += 1,
            }
        }
        let mut lat = LatencyStats::new();
        let mut total_symbols = 0usize;
        for rx in pending {
            let resp = rx.recv().unwrap();
            lat.record_us(resp.latency_us);
            total_symbols += resp.soft_symbols.len();
        }
        let wall = t0.elapsed().as_secs_f64();
        drop(client);
        pool.shutdown();
        let t = Throughput::from_rate(total_symbols as f64, wall);
        println!(
            "pool_admission {name:14} offered {:.0} rps  {}  p99 {:.0} us  \
             shed {:.0}%  full {:.0}%",
            offered,
            t.line(),
            lat.percentile_us(99.0),
            100.0 * shed as f64 / trace.len() as f64,
            100.0 * full as f64 / trace.len() as f64
        );
    }
}
