//! Figures 13/14/15: platform comparison (throughput / latency / power
//! vs symbols-per-batch).  Conventional platforms are calibrated models
//! (DESIGN.md §3); the FPGA rows come from the timing model + the
//! measured CPU-PJRT pipeline of this repo.

use equalizer::coordinator::seqlen::SeqLenOptimizer;
use equalizer::coordinator::timing::TimingModel;
use equalizer::equalizer::weights::CnnTopologyCfg;
use equalizer::hw::device::{XC7S25, XCVU13P};
use equalizer::hw::dop::Dop;
use equalizer::hw::platform;
use equalizer::hw::power::{ht_power_w, lp_power_w, lp_throughput_baud};
use equalizer::util::bench::Throughput;

const SPB_GRID: [u64; 10] =
    [8, 64, 400, 1_000, 10_000, 100_000, 1_000_000, 10_000_000, 100_000_000, 1_000_000_000];

fn main() {
    let cfg = CnnTopologyCfg::SELECTED;
    let m = TimingModel::new(64, cfg.vp, cfg.layers, cfg.kernel, 200e6);
    let opt = SeqLenOptimizer::new(m);
    let l = opt.min_l_inst(80e9).unwrap();
    let ht_baud = m.t_net(l) / cfg.n_os as f64;
    let ht_lat = m.lambda_sym_s(l);
    let ht_pow = ht_power_w(&cfg, 64, &XCVU13P);
    let lp_dop = *Dop::paper_sweep(&cfg).last().unwrap();
    let lp_baud = lp_throughput_baud(&cfg, lp_dop, &XC7S25);
    let lp_lat = 16.0 / lp_baud; // SPB 8 at the engine symbol rate
    let lp_pow = lp_power_w(&cfg, lp_dop, &XC7S25);

    let head = format!(
        "{:>12} | {:>11} {:>11} {:>11} {:>11} {:>11} | {:>11} {:>11}",
        "SPB", "RTX-PT", "RTX-TRT", "AGX-PT", "AGX-TRT", "CPU", "HT-FPGA", "LP-FPGA"
    );

    println!("=== Fig. 13: throughput (symbols/s) vs SPB ===\n{head}");
    for spb in SPB_GRID {
        print!("{spb:>12} |");
        for p in platform::ALL {
            print!(" {:>11.3e}", p.throughput(spb));
        }
        println!(" | {ht_baud:>11.3e} {lp_baud:>11.3e}");
    }
    println!(
        "anchor: HT-FPGA/RTX-TRT @400 SPB = {:.0}x (paper ~4500x); \
         RTX-TRT peak {:.1} GBd (paper 12)",
        ht_baud / platform::RTX_TENSORRT.throughput(400),
        platform::RTX_TENSORRT.throughput(u64::MAX / 2) / 1e9
    );
    // Unified records, cross-comparable with pipeline_hotpath /
    // serving_pool / `repro bench --json`.
    println!("unified: HT-FPGA {}", Throughput::from_rate(ht_baud, 1.0).line());
    println!("unified: LP-FPGA {}", Throughput::from_rate(lp_baud, 1.0).line());

    println!("\n=== Fig. 14: latency (s) vs SPB ===\n{head}");
    for spb in SPB_GRID {
        print!("{spb:>12} |");
        for p in platform::ALL {
            print!(" {:>11.3e}", p.latency(spb));
        }
        println!(" | {ht_lat:>11.3e} {lp_lat:>11.3e}");
    }
    println!(
        "anchor: AGX-TRT/HT-FPGA @1e6 SPB = {:.0}x (paper: up to 52x); \
         GPU/CPU ~{:.0}x HT at low SPB (paper ~5x)",
        platform::AGX_TENSORRT.latency(1_000_000) / ht_lat,
        platform::RTX_TENSORRT.latency(400) / ht_lat
    );

    println!("\n=== Fig. 15: power (W) vs SPB ===\n{head}");
    for spb in SPB_GRID {
        print!("{spb:>12} |");
        for p in platform::ALL {
            print!(" {:>11.1}", p.power(spb));
        }
        println!(" | {ht_pow:>11.1} {lp_pow:>11.3}");
    }
    println!(
        "anchors: CPU max {:.0} W (paper 93), RTX max {:.0} W (paper 250), HT ~2x AGX envelope",
        platform::CPU_I9.power(u64::MAX / 2),
        platform::RTX_PYTORCH.power(u64::MAX / 2)
    );
}
