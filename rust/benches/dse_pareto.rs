//! Figures 2 and 4: design-space-exploration Pareto fronts.
//!
//! Consumes the Python sweep output (`make dse` -> artifacts/dse_*.json),
//! applies the hardware-aware MAC ceiling, prints the fronts and the
//! selected configuration, and cross-checks the paper's headline claims
//! (CNN dominates FIR below ~1e-2 BER; FIR saturates; the selected
//! model is V_p=8/L=3/K=9/C=5-class).

use equalizer::dse::pareto::pareto_front;
use equalizer::dse::report::{DseFile, FigureReport};
use equalizer::hw::device::{XC7S25, XCVU13P};
use equalizer::util::bench::{header, Bencher};

fn main() {
    let dir = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));

    for (fig, file, dev, t_req) in [
        ("Fig. 2 (optical IM/DD)", "dse_imdd.json", &XCVU13P, 40e9),
        ("Fig. 4 (magnetic recording)", "dse_proakis.json", &XC7S25, 100e6),
    ] {
        println!("=== {fig} ===");
        let path = format!("{dir}/{file}");
        match DseFile::load(&path) {
            Err(_) => println!("({file} not found — run `make dse` first)\n"),
            Ok(f) => {
                println!(
                    "{} results ({} iters x {} seeds per config)",
                    f.results.len(),
                    f.iters,
                    f.seeds
                );
                let rep = FigureReport::build(&f, dev, t_req);
                print!("{}", rep.render());

                // Headline shape checks (printed, not asserted — the
                // figures_smoke test asserts the invariant parts).
                let cnn = rep.fronts.iter().find(|(n, _)| n == "cnn");
                let fir = rep.fronts.iter().find(|(n, _)| n == "fir");
                if let (Some((_, cnn)), Some((_, fir))) = (cnn, fir) {
                    let best_fir = fir.last().map(|p| p.ber).unwrap_or(1.0);
                    let best_cnn = cnn.last().map(|p| p.ber).unwrap_or(1.0);
                    println!(
                        "FIR floor {best_fir:.3e} vs best CNN {best_cnn:.3e}  \
                         (paper: FIR saturates above the CNN)"
                    );
                    // Matched-complexity comparison around the selection.
                    if let Some(sel) = &rep.selected {
                        // Closest FIR at >= 80% of the selection's
                        // complexity, else the FIR front's floor (its
                        // Pareto front ends where more taps stop helping).
                        let near_fir = fir
                            .iter()
                            .filter(|p| p.mac_per_symbol >= sel.mac_per_symbol * 0.8)
                            .map(|p| p.ber)
                            .fold(f64::INFINITY, f64::min)
                            .min(fir.last().map(|p| p.ber).unwrap_or(f64::INFINITY));
                        println!(
                            "equal-complexity gap: FIR {near_fir:.3e} / CNN {:.3e} = {:.1}x \
                             (paper: ~4x optical, ~1.1x magnetic)\n",
                            sel.ber,
                            near_fir / sel.ber.max(1e-9)
                        );
                    }
                }
            }
        }
    }

    header("pareto extraction cost");
    let b = Bencher::default();
    if let Ok(f) = DseFile::load(format!("{dir}/dse_imdd.json")) {
        let pts = f.points("cnn");
        b.bench(&format!("pareto_front over {} cnn points", pts.len()), || {
            pareto_front(&pts)
        });
        b.bench("dse_file_parse", || DseFile::load(format!("{dir}/dse_imdd.json")).unwrap());
    }
}
