//! Bench + figure: Sec. 6.1 timing model vs cycle-approximate simulator
//! (regenerates Fig. 12; model-vs-sim error percentages are the
//! reproduction target — paper reports ~6% latency / ~0.1% throughput
//! on its own hardware sim).

use equalizer::coordinator::sim::simulate;
use equalizer::coordinator::timing::TimingModel;
use equalizer::util::bench::{header, Bencher};

fn main() {
    println!("=== Fig. 12: timing model vs cycle-approximate simulation ===");
    for n_i in [2usize, 8, 64] {
        let m = TimingModel::new(n_i, 8, 3, 9, 200e6);
        println!("\n-- N_i = {n_i} (T_max {:.1} Gsa/s) --", m.t_max() / 1e9);
        println!(
            "{:>8} {:>12} {:>12} {:>8} {:>12} {:>12} {:>8}",
            "l_inst", "lam_mod us", "lam_sim us", "err%", "Tnet_mod G", "Tnet_sim G", "err%"
        );
        for l_inst in [1024usize, 2048, 4096, 7320, 16384, 32768] {
            let sim = simulate(&m, l_inst, (16 * n_i).max(64));
            let lam_m = m.lambda_sym_s(l_inst) * 1e6;
            let lam_s = sim.lambda_sym_s * 1e6;
            let tn_m = m.t_net(l_inst) / 1e9;
            let tn_s = sim.t_net / 1e9;
            println!(
                "{:>8} {:>12.2} {:>12.2} {:>8.1} {:>12.2} {:>12.2} {:>8.1}",
                l_inst,
                lam_m,
                lam_s,
                (lam_s - lam_m).abs() / lam_m * 100.0,
                tn_m,
                tn_s,
                (tn_s - tn_m).abs() / tn_m * 100.0
            );
        }
    }

    println!("\n=== Sec. 7.1 anchor ===");
    let m = TimingModel::new(64, 8, 3, 9, 200e6);
    println!(
        "l_inst 7320 -> T_net {:.2} Gsa/s, lambda {:.2} us  (paper: 80 Gsa/s, 17.5 us)",
        m.t_net(7320) / 1e9,
        m.lambda_sym_s(7320) * 1e6
    );

    header("harness performance (cost of the framework itself)");
    let b = Bencher::default();
    b.bench("timing_model_eval (t_net + lambda)", || {
        let m = TimingModel::new(64, 8, 3, 9, 200e6);
        (m.t_net(7320), m.lambda_sym_s(7320))
    });
    b.bench("cycle_sim n_i=64, 1024 chunks", || simulate(&m, 7320, 1024));
    b.bench("cycle_sim n_i=8, 128 chunks", || {
        let m8 = TimingModel::new(8, 8, 3, 9, 200e6);
        simulate(&m8, 7320, 128)
    });
}
