"""AOT bridge: HLO-text export of the Pallas-lowered graphs."""

import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def folded():
    cfg = model.SELECTED
    params = model.cnn_init(cfg, jax.random.PRNGKey(0))
    params.pop("cfg")
    bn = model.cnn_bn_state(cfg)
    return model.cnn_fold_bn(params, bn, cfg), cfg


class TestHloExport:
    def test_text_no_custom_call(self, folded):
        """interpret=True Pallas must lower to plain HLO — the CPU PJRT
        client in the Rust runtime cannot execute Mosaic custom-calls."""
        os.environ["EQ_USE_PALLAS"] = "1"
        f, cfg = folded
        lowered = jax.jit(lambda x: (model.cnn_forward_folded(f, x, cfg),)).lower(
            jax.ShapeDtypeStruct((256,), jnp.float32)
        )
        text = aot.to_hlo_text(lowered)
        assert "custom-call" not in text
        assert "HloModule" in text
        assert "f32[256]" in text  # parameter shape visible
        assert "f32[128]" in text  # output symbols

    def test_quant_variant_exports(self, folded):
        os.environ["EQ_USE_PALLAS"] = "1"
        f, cfg = folded
        bits = {k: (4, 8) for k in ["a_in", "w0", "a0", "w1", "a1", "w2", "a2"]}
        lowered = jax.jit(
            lambda x: (model.cnn_forward_folded(f, x, cfg, quant_bits=bits),)
        ).lower(jax.ShapeDtypeStruct((256,), jnp.float32))
        text = aot.to_hlo_text(lowered)
        assert "custom-call" not in text

    def test_weight_roundtrip(self, folded, tmp_path):
        _, cfg = folded
        params = model.cnn_init(cfg, jax.random.PRNGKey(1))
        cfg_meta = params.pop("cfg")
        params["cfg"] = cfg_meta
        bn = model.cnn_bn_state(cfg)
        p = tmp_path / "w.json"
        aot.save_weights(str(p), params, bn, cfg, ber=1e-3)
        p2, bn2, cfg2, ber = aot.load_weights(str(p))
        assert cfg2 == cfg and ber == 1e-3
        import numpy as np

        np.testing.assert_allclose(
            np.asarray(p2["w0"]), np.asarray(params["w0"]), atol=1e-7
        )

    def test_default_bits_cover_selected(self):
        cfg = model.SELECTED
        for li in range(cfg.layers):
            assert f"w{li}" in aot.DEFAULT_BITS
            assert f"a{li}" in aot.DEFAULT_BITS
