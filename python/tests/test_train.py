"""Training-loop behaviour (build-time, Sec. 3.4): Adam, convergence."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

os.environ.setdefault("EQ_USE_PALLAS", "0")

from compile import channels, model, train


class TestAdam:
    def test_quadratic_convergence(self):
        params = {"x": jnp.array([5.0, -3.0])}
        st = train.adam_init(params)
        for _ in range(400):
            g = {"x": 2.0 * params["x"]}
            params, st = train.adam_update(params, g, st, lr=0.05)
        np.testing.assert_allclose(np.asarray(params["x"]), 0.0, atol=1e-2)

    def test_bias_correction_first_step(self):
        """First update magnitude ~ lr regardless of gradient scale."""
        for scale in [1e-3, 1.0, 1e3]:
            params = {"x": jnp.array(0.0)}
            st = train.adam_init(params)
            new, _ = train.adam_update(params, {"x": jnp.array(scale)}, st, lr=0.01)
            assert float(new["x"]) == pytest.approx(-0.01, rel=1e-3)


class TestBer:
    def test_perfect(self):
        s = channels.prbs(1000, 0)
        assert train.ber(s, s) == 0.0

    def test_inverted(self):
        s = channels.prbs(1000, 0)
        assert train.ber(-s, s) == 1.0

    def test_soft_decisions(self):
        assert train.ber(np.array([0.1, -0.9]), np.array([1.0, 1.0])) == 0.5


@pytest.fixture(scope="module")
def proakis_data():
    return channels.proakis_b(20000, seed=0, snr_db=25.0), channels.proakis_b(
        8000, seed=99, snr_db=25.0
    )


class TestTrainingLoops:
    def test_fir_learns_channel(self, proakis_data):
        """A linear channel must be nearly invertible by the FIR."""
        data, ev = proakis_data
        r = train.train_fir(model.FirConfig(taps=25), data, iters=400, eval_data=ev)
        assert r.ber < 0.05
        assert r.loss_curve[-1] < r.loss_curve[0]

    def test_cnn_loss_decreases(self, proakis_data):
        data, ev = proakis_data
        cfg = model.CnnConfig(vp=4, layers=3, kernel=9, channels=3)
        r = train.train_cnn(cfg, data, iters=250, eval_data=ev)
        assert r.loss_curve[-1] < r.loss_curve[0]
        assert 0.0 <= r.ber <= 0.5

    def test_volterra_loss_decreases(self, proakis_data):
        data, ev = proakis_data
        cfg = model.VolterraConfig(m1=9, m2=3, m3=1)
        r = train.train_volterra(cfg, data, iters=200, eval_data=ev)
        assert r.loss_curve[-1] < r.loss_curve[0]

    def test_cnn_deterministic_given_seed(self, proakis_data):
        data, ev = proakis_data
        cfg = model.CnnConfig(vp=2, layers=3, kernel=9, channels=3)
        r1 = train.train_cnn(cfg, data, iters=30, seed=5, eval_data=ev)
        r2 = train.train_cnn(cfg, data, iters=30, seed=5, eval_data=ev)
        w1 = np.asarray(r1.params["w0"])
        w2 = np.asarray(r2.params["w0"])
        np.testing.assert_allclose(w1, w2, atol=1e-6)
