"""L2 model properties: topology template, BN folding, MAC accounting."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

os.environ.setdefault("EQ_USE_PALLAS", "0")  # oracle path: fast, identical

from compile import model


def _mk(cfg, seed=0):
    params = model.cnn_init(cfg, jax.random.PRNGKey(seed))
    params.pop("cfg")
    return params, model.cnn_bn_state(cfg)


class TestTopologyTemplate:
    @pytest.mark.parametrize("vp", [1, 2, 4, 8, 16])
    @pytest.mark.parametrize("layers", [3, 4, 5])
    def test_output_symbol_count(self, vp, layers):
        """Every grid config maps W input samples to W/N_os symbols."""
        cfg = model.CnnConfig(vp=vp, layers=layers, kernel=9, channels=3)
        params, bn = _mk(cfg)
        w_in = 32 * vp  # divisible by 2*vp
        x = jax.random.normal(jax.random.PRNGKey(1), (w_in,))
        y, _ = model.cnn_forward(params, bn, x, cfg)
        assert y.shape == (w_in // cfg.n_os,)
        assert cfg.out_symbols(w_in) == w_in // cfg.n_os

    @pytest.mark.parametrize("k", [9, 15, 21])
    def test_kernel_sizes(self, k):
        cfg = model.CnnConfig(vp=4, layers=3, kernel=k, channels=3)
        params, bn = _mk(cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (256,))
        y, _ = model.cnn_forward(params, bn, x, cfg)
        assert y.shape == (128,)

    def test_strides_structure(self):
        cfg = model.CnnConfig(vp=8, layers=5, kernel=9, channels=4)
        assert cfg.strides() == [8, 1, 1, 1, 2]

    def test_layer_channels(self):
        cfg = model.SELECTED
        assert cfg.layer_channels() == [(1, 5), (5, 5), (5, 8)]

    def test_mac_per_symbol_paper_formula(self):
        """Selected model: 9*5/8 + 1*9*5*5/8 + 9*5/2 = 56.25."""
        assert model.SELECTED.mac_per_symbol() == pytest.approx(56.25)

    def test_receptive_field_selected(self):
        """o_sym for (K=9, V_p=8, L=3): (9-1)(1+8*2)/2 = 68."""
        assert model.SELECTED.receptive_field_symbols() == 68

    def test_batch_forward_matches_single(self):
        cfg = model.SELECTED
        params, bn = _mk(cfg)
        xb = jax.random.normal(jax.random.PRNGKey(2), (3, 256))
        yb, _ = model.cnn_forward_batch(params, bn, xb, cfg)
        for i in range(3):
            yi, _ = model.cnn_forward(params, bn, xb[i], cfg)
            np.testing.assert_allclose(np.asarray(yb[i]), np.asarray(yi), atol=1e-5)


class TestBnFolding:
    def test_folded_equals_inference(self):
        """conv+BN+ReLU (running stats) == foldedconv+ReLU, bitwise-close."""
        cfg = model.SELECTED
        params, bn = _mk(cfg)
        # Non-trivial BN state
        for k in bn:
            key = jax.random.PRNGKey(hash(k) % 2**31)
            if "mean" in k:
                bn[k] = 0.3 * jax.random.normal(key, bn[k].shape)
            else:
                bn[k] = 0.5 + jax.random.uniform(key, bn[k].shape)
        params["bn0_gamma"] = 1.0 + 0.1 * jnp.arange(5, dtype=jnp.float32)
        params["bn0_beta"] = 0.05 * jnp.arange(5, dtype=jnp.float32)

        x = jax.random.normal(jax.random.PRNGKey(3), (512,))
        y_ref, _ = model.cnn_forward(params, bn, x, cfg, train=False)
        folded = model.cnn_fold_bn(params, bn, cfg)
        y_fold = model.cnn_forward_folded(folded, x, cfg)
        np.testing.assert_allclose(np.asarray(y_fold), np.asarray(y_ref), atol=1e-4)

    def test_fold_preserves_shapes(self):
        cfg = model.CnnConfig(vp=2, layers=4, kernel=15, channels=4)
        params, bn = _mk(cfg)
        folded = model.cnn_fold_bn(params, bn, cfg)
        for li, (cin, cout) in enumerate(cfg.layer_channels()):
            assert folded[f"w{li}"].shape == (cout, cin, cfg.kernel)
            assert folded[f"b{li}"].shape == (cout,)


class TestQuantForward:
    def test_quant_close_to_fp_at_wide_widths(self):
        cfg = model.SELECTED
        params, bn = _mk(cfg)
        folded = model.cnn_fold_bn(params, bn, cfg)
        x = jax.random.normal(jax.random.PRNGKey(4), (256,))
        y_fp = model.cnn_forward_folded(folded, x, cfg)
        bits = {k: (8, 14) for k in ["a_in", "w0", "a0", "w1", "a1", "w2", "a2"]}
        y_q = model.cnn_forward_folded(folded, x, cfg, quant_bits=bits)
        np.testing.assert_allclose(np.asarray(y_q), np.asarray(y_fp), atol=2e-3)

    def test_narrow_quant_changes_output(self):
        cfg = model.SELECTED
        params, bn = _mk(cfg)
        folded = model.cnn_fold_bn(params, bn, cfg)
        x = jax.random.normal(jax.random.PRNGKey(4), (256,))
        y_fp = model.cnn_forward_folded(folded, x, cfg)
        bits = {k: (2, 2) for k in ["a_in", "w0", "a0", "w1", "a1", "w2", "a2"]}
        y_q = model.cnn_forward_folded(folded, x, cfg, quant_bits=bits)
        assert float(jnp.max(jnp.abs(y_q - y_fp))) > 1e-3


class TestFir:
    def test_identity_taps(self):
        cfg = model.FirConfig(taps=9)
        w = jnp.zeros((9,)).at[4].set(1.0)
        x = jax.random.normal(jax.random.PRNGKey(5), (64,))
        y = model.fir_forward({"w": w}, x, cfg)
        np.testing.assert_allclose(np.asarray(y), np.asarray(x)[::2], atol=1e-6)

    def test_mac_count(self):
        assert model.FirConfig(taps=57).mac_per_symbol() == 57.0


class TestVolterra:
    def test_mac_count(self):
        cfg = model.VolterraConfig(m1=25, m2=3, m3=3)
        assert cfg.mac_per_symbol() == 25 + 9 + 27

    def test_forward_shape(self):
        cfg = model.VolterraConfig(m1=9, m2=3, m3=3)
        params = model.volterra_init(cfg, jax.random.PRNGKey(6))
        x = jax.random.normal(jax.random.PRNGKey(7), (128,))
        y = model.volterra_forward(params, x, cfg)
        assert y.shape == (64,)
