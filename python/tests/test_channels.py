"""Channel-simulator properties (Sec. 2 substrates)."""

import numpy as np
import pytest

from compile import channels


class TestPrbs:
    def test_deterministic(self):
        a = channels.prbs(1000, seed=7)
        b = channels.prbs(1000, seed=7)
        np.testing.assert_array_equal(a, b)

    def test_binary_and_balanced(self):
        s = channels.prbs(20000, seed=0)
        assert set(np.unique(s)) == {-1.0, 1.0}
        assert abs(s.mean()) < 0.05

    def test_seed_changes_sequence(self):
        assert not np.array_equal(channels.prbs(100, 0), channels.prbs(100, 1))


class TestFilters:
    def test_rrc_unit_energy(self):
        taps = channels.rrc_taps(0.2, 32, 2)
        assert np.sum(taps**2) == pytest.approx(1.0, abs=1e-9)

    def test_rrc_symmetric(self):
        taps = channels.rrc_taps(0.2, 16, 2)
        np.testing.assert_allclose(taps[1:], taps[1:][::-1], atol=1e-9)

    def test_rc_nyquist_zero_crossings(self):
        """RC pulse is ~0 at nonzero symbol-spaced offsets (ISI-free)."""
        sps = 2
        taps = channels.rc_taps(0.3, 16, sps)
        center = len(taps) // 2
        for k in range(1, 6):
            assert abs(taps[center + k * sps]) < 1e-6


class TestImdd:
    def test_shapes_and_rate(self):
        d = channels.imdd(5000, seed=0)
        assert d.rx.shape == (5000 * channels.N_OS,)
        assert d.symbols.shape == (5000,)
        assert d.rx.dtype == np.float32

    def test_normalized(self):
        d = channels.imdd(20000, seed=0)
        assert abs(float(d.rx.mean())) < 0.05
        assert float(d.rx.std()) == pytest.approx(1.0, abs=0.1)

    def test_symbol_correlation(self):
        """Symbol-position samples must carry symbol information."""
        d = channels.imdd(20000, seed=0)
        sym_samples = d.rx[:: channels.N_OS]
        c = np.corrcoef(sym_samples, d.symbols)[0, 1]
        assert abs(c) > 0.3, f"rx decorrelated from symbols (c={c})"

    def test_nonlinear_residual(self):
        """CD + square-law must leave an ISI floor a 1-tap scaler can't fix.

        The best single-coefficient linear estimate of the symbols from
        the aligned samples must still misdetect some symbols at 20 dB —
        the nonlinearity the CNN exists to fix.
        """
        d = channels.imdd(40000, seed=0, snr_db=30.0)
        x = d.rx[:: channels.N_OS]
        a = float(np.dot(x, d.symbols) / np.dot(x, x))
        dec = np.where(a * x >= 0, 1.0, -1.0)
        ber = np.mean(dec != d.symbols)
        assert ber > 1e-3

    def test_dispersion_spreads_energy(self):
        """Longer fiber -> more ISI -> lower symbol-sample correlation."""
        c = []
        for km in [1.0, 31.5]:
            d = channels.imdd(20000, seed=0, fiber_km=km, snr_db=40.0)
            c.append(abs(np.corrcoef(d.rx[:: channels.N_OS], d.symbols)[0, 1]))
        assert c[1] < c[0]

    def test_deterministic(self):
        a = channels.imdd(1000, seed=3)
        b = channels.imdd(1000, seed=3)
        np.testing.assert_array_equal(a.rx, b.rx)


class TestProakisB:
    def test_shapes(self):
        d = channels.proakis_b(5000, seed=0)
        assert d.rx.shape == (10000,)
        assert d.symbols.shape == (5000,)

    def test_impulse_response_is_proakis_b(self):
        np.testing.assert_allclose(channels.H_PROAKIS_B, [0.407, 0.815, 0.407])

    def test_linear_channel_is_linear(self):
        """Superposition: rx(a+b) == rx(a) + rx(b) (noise-free)."""
        import compile.channels as ch

        def tx(symbols):
            shaped = np.convolve(
                ch._upsample(symbols, ch.N_OS), ch.rc_taps(0.3, 16, ch.N_OS), "same"
            )
            h_up = np.zeros((len(ch.H_PROAKIS_B) - 1) * ch.N_OS + 1)
            h_up[:: ch.N_OS] = ch.H_PROAKIS_B
            return np.convolve(shaped, h_up, "same")

        rng = np.random.RandomState(0)
        a = rng.randn(500)
        b = rng.randn(500)
        np.testing.assert_allclose(tx(a + b), tx(a) + tx(b), atol=1e-9)

    def test_snr_controls_noise(self):
        lo = channels.proakis_b(5000, seed=0, snr_db=5.0)
        hi = channels.proakis_b(5000, seed=0, snr_db=30.0)
        # Same symbols, different noise level: high-SNR rx correlates better.
        c_lo = abs(np.corrcoef(lo.rx[::2], lo.symbols)[0, 1])
        c_hi = abs(np.corrcoef(hi.rx[::2], hi.symbols)[0, 1])
        assert c_hi > c_lo


class TestWindows:
    def test_shapes_and_alignment(self):
        d = channels.proakis_b(4000, seed=0)
        x, y = channels.windows(d, seq_sym=128)
        assert x.shape[1] == 256 and y.shape[1] == 128
        assert x.shape[0] == y.shape[0] == 4000 // 128
        np.testing.assert_array_equal(y[0], d.symbols[:128])
        np.testing.assert_array_equal(x[1], d.rx[256:512])

    def test_overlapping_stride(self):
        d = channels.proakis_b(1000, seed=0)
        x, y = channels.windows(d, seq_sym=100, stride_sym=50)
        assert x.shape[0] == (1000 - 100) // 50 + 1
