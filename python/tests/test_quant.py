"""Quantization-aware training mechanics (Sec. 4)."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

os.environ.setdefault("EQ_USE_PALLAS", "0")

from compile import channels, model, quant
from compile.kernels import ref


class TestSte:
    def test_value_matches_ref(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (64,)) * 3
        a = quant.fake_quant_ste(x, 4.0, 6.0)
        b = ref.fake_quant(x, 4.0, 6.0)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)

    def test_data_gradient_is_identity(self):
        g = jax.grad(lambda x: jnp.sum(quant.fake_quant_ste(x, 4.0, 6.0)))(
            jax.random.normal(jax.random.PRNGKey(1), (16,))
        )
        np.testing.assert_allclose(np.asarray(g), 1.0, atol=1e-6)

    def test_bits_gradient_nonzero(self):
        """Width gradient must flow (the paper's differentiable widths)."""
        x = jax.random.normal(jax.random.PRNGKey(2), (256,))

        def err(fb):
            q = quant.fake_quant_ste(x, 8.0, fb)
            return jnp.mean((q - x) ** 2)

        g = jax.grad(err)(3.5)
        assert abs(float(g)) > 0.0

    def test_more_frac_bits_less_error(self):
        x = jax.random.normal(jax.random.PRNGKey(3), (512,))
        errs = [
            float(jnp.mean((ref.fake_quant(x, 8.0, fb) - x) ** 2))
            for fb in [2.0, 4.0, 8.0, 12.0]
        ]
        assert errs == sorted(errs, reverse=True)


class TestBitBookkeeping:
    def test_init_is_32_bits(self):
        bits = quant.init_bit_params(model.SELECTED)
        for v in bits.values():
            assert float(jnp.sum(v)) == 32.0

    def test_frozen_bits_ceil(self):
        bits = {"w0": jnp.array([2.3, 9.1])}
        assert quant.frozen_bits(bits) == {"w0": (3, 10)}

    def test_frozen_bits_clip(self):
        bits = {"w0": jnp.array([0.2, 20.0])}
        assert quant.frozen_bits(bits) == {"w0": (1, 16)}

    def test_avg_bits(self):
        bits = {
            "w0": jnp.array([4.0, 8.0]),
            "w1": jnp.array([2.0, 6.0]),
            "a0": jnp.array([1.0, 1.0]),
        }
        assert float(quant.avg_bits(bits, "w")) == pytest.approx(10.0)


class TestQatEndToEnd:
    @pytest.fixture(scope="class")
    def result(self):
        data = channels.proakis_b(12000, seed=0, snr_db=25.0)
        ev = channels.proakis_b(6000, seed=99, snr_db=25.0)
        cfg = model.CnnConfig(vp=4, layers=3, kernel=9, channels=3)
        return quant.train_qat(
            cfg,
            data,
            qlf=5e-3,
            iters_fp=150,
            iters_bits=250,
            iters_ft=100,
            eval_every=100,
            eval_data=ev,
        )

    def test_bits_decrease(self, result):
        """QLF pressure must push widths below the 32-bit start."""
        phase2 = [h for h in result.history if h["phase"] >= 2]
        assert phase2[-1]["b_act"] < 32.0
        assert phase2[-1]["b_par"] < 32.0

    def test_history_covers_three_phases(self, result):
        assert {h["phase"] for h in result.history} == {1, 2, 3}

    def test_frozen_bits_are_integers(self, result):
        for ib, fb in result.bits.values():
            assert isinstance(ib, int) and isinstance(fb, int)
            assert 1 <= ib <= 16 and 1 <= fb <= 16

    def test_ber_sane(self, result):
        assert 0.0 <= result.ber <= 0.5
