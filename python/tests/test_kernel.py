"""L1 correctness: Pallas kernels vs pure-jnp oracles.

This is the core numeric signal of the build path — the exported HLO
artifacts contain the Pallas lowering, so any mismatch here would ship
into the Rust runtime.  hypothesis sweeps shapes/strides/padding.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv1d as pallas_conv
from compile.kernels import quant as pallas_quant
from compile.kernels import ref

settings.register_profile("ci", deadline=None, max_examples=25)
settings.load_profile("ci")


def _rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype=jnp.float32)


class TestConv1d:
    @given(
        cin=st.integers(1, 6),
        cout=st.integers(1, 9),
        k=st.sampled_from([3, 9, 15, 21]),
        width=st.integers(32, 400),
        stride=st.sampled_from([1, 2, 4, 8, 16]),
        relu=st.booleans(),
    )
    def test_matches_ref(self, cin, cout, k, width, stride, relu):
        if width + 2 * ((k - 1) // 2) < k:
            return
        x = _rand(0, (cin, width))
        w = _rand(1, (cout, cin, k))
        b = _rand(2, (cout,))
        pad = (k - 1) // 2
        got = pallas_conv.conv1d(x, w, b, stride, pad, relu=relu)
        want = ref.conv1d(x, w, b, stride, pad, relu=relu)
        assert got.shape == want.shape
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4, rtol=2e-4)

    def test_zero_padding_cases(self):
        x = _rand(0, (2, 64))
        w = _rand(1, (3, 2, 9))
        b = jnp.zeros((3,))
        got = pallas_conv.conv1d(x, w, b, 1, 0)
        want = ref.conv1d(x, w, b, 1, 0)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4)

    def test_identity_kernel(self):
        """A centered delta kernel must reproduce the input."""
        x = _rand(0, (1, 128))
        w = jnp.zeros((1, 1, 9)).at[0, 0, 4].set(1.0)
        out = pallas_conv.conv1d(x, w, jnp.zeros((1,)), 1, 4)
        np.testing.assert_allclose(np.asarray(out), np.asarray(x), atol=1e-5)

    def test_stride_decimates(self):
        x = _rand(0, (1, 128))
        w = jnp.zeros((1, 1, 9)).at[0, 0, 4].set(1.0)
        out = pallas_conv.conv1d(x, w, jnp.zeros((1,)), 2, 4)
        np.testing.assert_allclose(np.asarray(out)[0], np.asarray(x)[0, ::2], atol=1e-5)

    def test_bias_and_relu(self):
        x = _rand(0, (1, 64))
        w = jnp.zeros((2, 1, 3))
        b = jnp.array([1.5, -1.5])
        out = pallas_conv.conv1d(x, w, b, 1, 1, relu=True)
        assert np.allclose(np.asarray(out)[0], 1.5)
        assert np.allclose(np.asarray(out)[1], 0.0)

    def test_tile_boundary_widths(self):
        """Widths straddling the 128 tile: 127/128/129 outputs."""
        for width in [127, 128, 129, 255, 257]:
            x = _rand(3, (2, width))
            w = _rand(4, (2, 2, 9))
            b = _rand(5, (2,))
            got = pallas_conv.conv1d(x, w, b, 1, 4)
            want = ref.conv1d(x, w, b, 1, 4)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4)

    def test_vmem_estimate_positive(self):
        assert pallas_conv.vmem_bytes(5, 1024, 9, 5, 1) > 0
        assert 0 < pallas_conv.mxu_utilization(5, 9, 5) <= 1.0


class TestFakeQuant:
    @given(
        ib=st.integers(1, 8),
        fb=st.integers(0, 12),
        n=st.integers(1, 500),
    )
    def test_matches_ref_integer_widths(self, ib, fb, n):
        x = _rand(7, (n,)) * 4.0
        got = pallas_quant.fake_quant(x, ib, fb)
        want = ref.fake_quant(x, float(ib), float(fb))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)

    @given(ib=st.integers(2, 8), fb=st.integers(1, 10))
    def test_idempotent(self, ib, fb):
        x = _rand(8, (64,)) * 2.0
        q1 = pallas_quant.fake_quant(x, ib, fb)
        q2 = pallas_quant.fake_quant(q1, ib, fb)
        np.testing.assert_allclose(np.asarray(q1), np.asarray(q2), atol=1e-7)

    def test_saturation(self):
        x = jnp.array([100.0, -100.0])
        q = np.asarray(pallas_quant.fake_quant(x, 4, 4))
        assert q[0] == pytest.approx(8.0 - 1.0 / 16.0)
        assert q[1] == pytest.approx(-8.0)

    def test_grid_resolution(self):
        """All outputs land on the Q(m.n) grid."""
        x = _rand(9, (256,))
        q = np.asarray(pallas_quant.fake_quant(x, 3, 5))
        np.testing.assert_allclose(q * 32, np.round(q * 32), atol=1e-6)

    def test_interpolated_between_integer_widths(self):
        """Fractional widths interpolate monotonically in error."""
        x = _rand(10, (512,))
        e = []
        for fb in [4.0, 4.5, 5.0]:
            q = ref.fake_quant(x, 8.0, fb)
            e.append(float(jnp.mean((q - x) ** 2)))
        assert e[0] >= e[1] >= e[2]


class TestVolterraRef:
    def test_first_order_equals_fir(self):
        x = _rand(11, (100,))
        w1 = _rand(12, (9,))
        y_v = ref.volterra(x, jnp.zeros(()), w1, jnp.zeros((1, 1)), jnp.zeros((1, 1, 1)))
        y_f = ref.fir(x, w1)
        np.testing.assert_allclose(np.asarray(y_v), np.asarray(y_f), atol=1e-4)

    def test_second_order_square(self):
        """w2 = delta at center -> y = x^2 (plus first-order zero)."""
        x = _rand(13, (50,))
        w2 = jnp.zeros((3, 3)).at[1, 1].set(1.0)
        y = ref.volterra(x, jnp.zeros(()), jnp.zeros((1,)), w2, jnp.zeros((1, 1, 1)))
        np.testing.assert_allclose(np.asarray(y), np.asarray(x) ** 2, atol=1e-4)

    def test_third_order_cube(self):
        x = _rand(14, (50,))
        w3 = jnp.zeros((3, 3, 3)).at[1, 1, 1].set(1.0)
        y = ref.volterra(x, jnp.zeros(()), jnp.zeros((1,)), jnp.zeros((1, 1)), w3)
        np.testing.assert_allclose(np.asarray(y), np.asarray(x) ** 3, atol=1e-4)

    def test_bias(self):
        x = jnp.zeros((10,))
        y = ref.volterra(
            x, jnp.float32(2.5), jnp.zeros((1,)), jnp.zeros((1, 1)), jnp.zeros((1, 1, 1))
        )
        np.testing.assert_allclose(np.asarray(y), 2.5)


class TestRoundTiesEven:
    """round_ties_even replaces jnp.round in the export path (the
    round-nearest-even HLO op aborts the Rust runtime's XLA 0.5.1)."""

    @given(st.lists(st.floats(-100, 100, width=32), min_size=1, max_size=200))
    def test_matches_jnp_round(self, vals):
        x = jnp.asarray(vals, dtype=jnp.float32)
        np.testing.assert_array_equal(
            np.asarray(ref.round_ties_even(x)), np.asarray(jnp.round(x))
        )

    def test_exact_ties(self):
        x = jnp.asarray([0.5, 1.5, 2.5, -0.5, -1.5, -2.5])
        np.testing.assert_array_equal(
            np.asarray(ref.round_ties_even(x)), [0.0, 2.0, 2.0, -0.0, -2.0, -2.0]
        )
