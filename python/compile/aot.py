"""AOT export: train the selected equalizers and lower them to HLO text.

This is the only bridge between the Python build path and the Rust
request path.  For every model variant and input-width bucket it emits
``artifacts/<name>.hlo.txt`` plus a ``manifest.json`` the Rust artifact
registry consumes, and ``weights_*.json`` for the bit-accurate Rust
fixed-point datapath.

Interchange is HLO **text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the image's
xla_extension 0.5.1 (the version the published ``xla`` 0.1.6 crate
links) rejects; the text parser reassigns ids and round-trips cleanly
(see /opt/xla-example/README.md).

Trained weights are cached under ``artifacts/weights_*.json`` so
``make artifacts`` is cheap on re-runs; delete the cache to retrain.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import channels, model, train

# Input-width buckets (receiver samples) exported per model.  The Rust
# coordinator picks the bucket matching its sub-sequence length
# l_ol = l_inst + 2*o_act; all are divisible by 2*V_p = 16.
WIDTH_BUCKETS = [256, 512, 1024, 2048, 4096, 8192]
BATCHED = [(1024, 8)]  # (width, batch) variants for the batching ablation

# Default fixed-point formats if no QAT artifact is present (Sec. 4
# result: ~13 bit weights, ~10 bit activations).
DEFAULT_BITS = {
    "w0": (3, 10), "w1": (3, 10), "w2": (3, 10), "w3": (3, 10), "w4": (3, 10),
    "a_in": (4, 6), "a0": (4, 6), "a1": (4, 6), "a2": (4, 6), "a3": (4, 6), "a4": (4, 6),
}


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True: the default printer ELIDES big weight
    # literals as `constant({...})`, which the xla_extension 0.5.1 text
    # parser silently turns into zeros/garbage — the baked weights must
    # be printed in full.
    return comp.as_hlo_text(True)


def _tolist(t) -> list:
    return np.asarray(t).tolist()


def save_weights(path: str, params: dict, bn_state: dict, cfg: model.CnnConfig, ber: float) -> None:
    folded = model.cnn_fold_bn(
        {k: v for k, v in params.items() if k != "cfg"}, bn_state, cfg
    )
    out = {
        "cfg": dataclasses.asdict(cfg),
        "ber": ber,
        "raw": {k: _tolist(v) for k, v in params.items() if k != "cfg"},
        "bn": {k: _tolist(v) for k, v in bn_state.items()},
        "folded": {
            k: _tolist(v) for k, v in folded.items() if k != "cfg"
        },
    }
    with open(path, "w") as f:
        json.dump(out, f)


def load_weights(path: str) -> tuple[dict, dict, model.CnnConfig, float]:
    with open(path) as f:
        d = json.load(f)
    cfg = model.CnnConfig(**d["cfg"])
    params = {k: jnp.asarray(v) for k, v in d["raw"].items()}
    bn = {k: jnp.asarray(v) for k, v in d["bn"].items()}
    return params, bn, cfg, d["ber"]


def train_or_load_cnn(
    art: str, channel: str, iters: int, n_sym: int
) -> tuple[dict, dict, model.CnnConfig, float]:
    cache = os.path.join(art, f"weights_cnn_{channel}.json")
    if os.path.exists(cache):
        print(f"[aot] using cached {cache}")
        return load_weights(cache)
    cfg = model.SELECTED
    print(f"[aot] training CNN {cfg} on {channel} ({iters} iters)...")
    data = channels.make_dataset(channel, n_sym, seed=0)
    eval_data = channels.make_dataset(channel, n_sym // 2, seed=1000)
    t0 = time.time()
    r = train.train_cnn(cfg, data, iters=iters, seq_sym=256, eval_data=eval_data)
    print(f"[aot] trained in {time.time()-t0:.1f}s, BER={r.ber:.3e}")
    save_weights(cache, r.params, r.bn_state, cfg, r.ber)
    return (
        {k: v for k, v in r.params.items() if k != "cfg"},
        r.bn_state,
        cfg,
        r.ber,
    )


def train_or_load_fir(art: str, channel: str, iters: int, n_sym: int, taps: int = 57):
    cache = os.path.join(art, f"weights_fir_{channel}.json")
    cfg = model.FirConfig(taps=taps)
    if os.path.exists(cache):
        with open(cache) as f:
            d = json.load(f)
        return {"w": jnp.asarray(d["w"])}, model.FirConfig(**d["cfg"]), d["ber"]
    print(f"[aot] training FIR M={taps} on {channel}...")
    data = channels.make_dataset(channel, n_sym, seed=0)
    eval_data = channels.make_dataset(channel, n_sym // 2, seed=1000)
    r = train.train_fir(cfg, data, iters=iters, eval_data=eval_data)
    print(f"[aot] FIR BER={r.ber:.3e}")
    with open(cache, "w") as f:
        json.dump({"cfg": dataclasses.asdict(cfg), "w": _tolist(r.params["w"]), "ber": r.ber}, f)
    return r.params, cfg, r.ber


def train_or_load_volterra(art: str, channel: str, iters: int, n_sym: int, m=(25, 3, 3)):
    cache = os.path.join(art, f"weights_volterra_{channel}.json")
    cfg = model.VolterraConfig(m1=m[0], m2=m[1], m3=m[2])
    if os.path.exists(cache):
        with open(cache) as f:
            d = json.load(f)
        return (
            {k: jnp.asarray(v) for k, v in d["params"].items()},
            model.VolterraConfig(**d["cfg"]),
            d["ber"],
        )
    print(f"[aot] training Volterra {m} on {channel}...")
    data = channels.make_dataset(channel, n_sym, seed=0)
    eval_data = channels.make_dataset(channel, n_sym // 2, seed=1000)
    r = train.train_volterra(cfg, data, iters=iters, eval_data=eval_data)
    print(f"[aot] Volterra BER={r.ber:.3e}")
    with open(cache, "w") as f:
        json.dump(
            {
                "cfg": dataclasses.asdict(cfg),
                "params": {k: _tolist(v) for k, v in r.params.items()},
                "ber": r.ber,
            },
            f,
        )
    return r.params, cfg, r.ber


def qat_bits(art: str, channel: str, cfg: model.CnnConfig) -> dict[str, tuple[int, int]]:
    """Learned fixed-point formats from the QAT artifact, or defaults."""
    path = os.path.join(art, f"qat_bits_{channel}.json")
    if os.path.exists(path):
        with open(path) as f:
            return {k: tuple(v) for k, v in json.load(f).items()}
    return {k: v for k, v in DEFAULT_BITS.items()}


def export(lowered_fn, example, name: str, art: str, manifest: list, meta: dict) -> None:
    lowered = jax.jit(lowered_fn).lower(example)
    text = to_hlo_text(lowered)
    path = os.path.join(art, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    manifest.append(
        {
            "name": name,
            "path": f"{name}.hlo.txt",
            "input_shape": list(example.shape),
            **meta,
        }
    )
    print(f"[aot] wrote {path} ({len(text)} chars)")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts/model.hlo.txt", help="sentinel path (Makefile)")
    ap.add_argument("--iters", type=int, default=int(os.environ.get("EQ_AOT_ITERS", "8000")))
    ap.add_argument("--n-sym", type=int, default=200_000)
    ap.add_argument("--widths", default=",".join(map(str, WIDTH_BUCKETS)))
    args = ap.parse_args()

    art = os.path.dirname(os.path.abspath(args.out)) or "../artifacts"
    os.makedirs(art, exist_ok=True)
    widths = [int(w) for w in args.widths.split(",")]
    manifest: list[dict] = []

    # Training sweeps run on the jnp oracle for speed; the *exported*
    # graphs below keep EQ_USE_PALLAS=1 so the L1 Pallas kernel is what
    # actually lowers into the artifacts.
    os.environ["EQ_USE_PALLAS"] = "0"

    # --- optical (IM/DD) models -------------------------------------
    params, bn, cfg, cnn_ber = train_or_load_cnn(art, "imdd", args.iters, args.n_sym)
    folded = model.cnn_fold_bn(params, bn, cfg)
    fir_p, fir_cfg, fir_ber = train_or_load_fir(art, "imdd", max(800, args.iters // 2), args.n_sym)
    vol_p, vol_cfg, vol_ber = train_or_load_volterra(
        art, "imdd", max(800, args.iters // 2), args.n_sym
    )

    # --- magnetic recording (Proakis-B) model ------------------------
    params_mr, bn_mr, cfg_mr, cnn_mr_ber = train_or_load_cnn(
        art, "proakis", max(1000, args.iters // 2), args.n_sym // 2
    )
    folded_mr = model.cnn_fold_bn(params_mr, bn_mr, cfg_mr)

    os.environ["EQ_USE_PALLAS"] = "1"
    bits = qat_bits(art, "imdd", cfg)

    for w in widths:
        example = jax.ShapeDtypeStruct((w,), jnp.float32)
        export(
            lambda x: (model.cnn_forward_folded(folded, x, cfg),),
            example,
            f"cnn_imdd_w{w}",
            art,
            manifest,
            {"model": "cnn", "channel": "imdd", "vp": cfg.vp,
             "out_symbols": cfg.out_symbols(w), "quant": False, "batch": 1},
        )

    # Quantized variant (static Pallas fake-quant baked in) — numerics
    # reference for the Rust fixed-point datapath.
    for w in [1024]:
        example = jax.ShapeDtypeStruct((w,), jnp.float32)
        export(
            lambda x: (model.cnn_forward_folded(folded, x, cfg, quant_bits=bits),),
            example,
            f"cnn_imdd_quant_w{w}",
            art,
            manifest,
            {"model": "cnn_quant", "channel": "imdd", "vp": cfg.vp,
             "out_symbols": cfg.out_symbols(w), "quant": True, "batch": 1,
             "bits": {k: list(v) for k, v in bits.items()}},
        )

    # Batched variants for the platform-comparison harness.
    for w, b in BATCHED:
        example = jax.ShapeDtypeStruct((b, w), jnp.float32)
        export(
            lambda x: (jax.vmap(lambda xi: model.cnn_forward_folded(folded, xi, cfg))(x),),
            example,
            f"cnn_imdd_w{w}_b{b}",
            art,
            manifest,
            {"model": "cnn", "channel": "imdd", "vp": cfg.vp,
             "out_symbols": cfg.out_symbols(w), "quant": False, "batch": b},
        )

    # Baselines.
    for w in [1024, 4096]:
        example = jax.ShapeDtypeStruct((w,), jnp.float32)
        export(
            lambda x: (model.fir_forward(fir_p, x, fir_cfg),),
            example,
            f"fir_imdd_w{w}",
            art,
            manifest,
            {"model": "fir", "channel": "imdd", "taps": fir_cfg.taps,
             "out_symbols": w // 2, "quant": False, "batch": 1},
        )
    example = jax.ShapeDtypeStruct((1024,), jnp.float32)
    export(
        lambda x: (model.volterra_forward(vol_p, x, vol_cfg),),
        example,
        "volterra_imdd_w1024",
        art,
        manifest,
        {"model": "volterra", "channel": "imdd",
         "m": [vol_cfg.m1, vol_cfg.m2, vol_cfg.m3],
         "out_symbols": 512, "quant": False, "batch": 1},
    )

    # Magnetic-recording CNN (LP scenario).
    for w in [1024]:
        example = jax.ShapeDtypeStruct((w,), jnp.float32)
        export(
            lambda x: (model.cnn_forward_folded(folded_mr, x, cfg_mr),),
            example,
            f"cnn_proakis_w{w}",
            art,
            manifest,
            {"model": "cnn", "channel": "proakis", "vp": cfg_mr.vp,
             "out_symbols": cfg_mr.out_symbols(w), "quant": False, "batch": 1},
        )

    # Numeric test vectors: the Rust integration tests replay these
    # through PJRT and the native datapath (tests/artifact_numerics.rs).
    rng = np.random.RandomState(123)
    xv = rng.randn(1024).astype(np.float32)
    tv = {"x": xv.tolist(), "outputs": {}}
    tv["outputs"]["cnn_imdd_w1024"] = _tolist(
        model.cnn_forward_folded(folded, jnp.asarray(xv), cfg)
    )
    tv["outputs"]["cnn_imdd_quant_w1024"] = _tolist(
        model.cnn_forward_folded(folded, jnp.asarray(xv), cfg, quant_bits=bits)
    )
    tv["outputs"]["fir_imdd_w1024"] = _tolist(
        model.fir_forward(fir_p, jnp.asarray(xv), fir_cfg)
    )
    tv["outputs"]["volterra_imdd_w1024"] = _tolist(
        model.volterra_forward(vol_p, jnp.asarray(xv), vol_cfg)
    )
    with open(os.path.join(art, "testvectors.json"), "w") as f:
        json.dump(tv, f)

    with open(os.path.join(art, "manifest.json"), "w") as f:
        json.dump(
            {
                "models": manifest,
                "ber": {
                    "cnn_imdd": cnn_ber,
                    "fir_imdd": fir_ber,
                    "volterra_imdd": vol_ber,
                    "cnn_proakis": cnn_mr_ber,
                },
                "selected_cfg": dataclasses.asdict(cfg),
            },
            f,
            indent=1,
        )

    # Sentinel for the Makefile dependency.
    with open(args.out, "w") as f:
        f.write(open(os.path.join(art, f"cnn_imdd_w{widths[0]}.hlo.txt")).read())
    print(f"[aot] manifest with {len(manifest)} models -> {art}/manifest.json")


if __name__ == "__main__":
    main()
