"""Communication-channel simulators used to generate training data.

Two channels, matching the paper (Sec. 2):

* :func:`imdd` — 40 GBd PAM-2 intensity-modulation / direct-detection
  optical link.  The paper captures this channel experimentally; we
  simulate the same impairment mechanism: an RRC-shaped PAM-2 drive
  signal modulates the optical *field*, chromatic dispersion (CD) of a
  31.5 km standard single-mode fiber is applied as an all-pass filter in
  the field domain, and a photodiode performs square-law detection
  ``y = |e|^2``.  Because CD acts on the field while detection is on the
  intensity, the composite channel is *nonlinear* — exactly the effect
  the CNN equalizer exploits and a linear FIR cannot invert (DESIGN.md
  §3, substitution table).

* :func:`proakis_b` — the simulated "magnetic recording" channel of
  Sec. 2.2: raised-cosine pulse shaping, discrete impulse response
  ``h = [0.407, 0.815, 0.407]`` (Proakis-B), additive white Gaussian
  noise.  Linear by construction.

Both run at an oversampling factor ``N_os = 2`` and use a
Mersenne-Twister PRBS (numpy ``RandomState`` == MT19937), following the
paper's recommendation of [18].
"""

from __future__ import annotations

import dataclasses

import numpy as np

N_OS = 2  # oversampling factor used throughout the paper

# Physical constants / fiber parameters (Sec. 2.1)
_C_LIGHT = 299_792_458.0  # m/s
_LAMBDA = 1550e-9  # m
_D_CD = 16e-6  # s/m^2  (= 16 ps / (nm km))
_FIBER_KM = 31.5
_BAUD = 40e9  # 40 GBd


@dataclasses.dataclass(frozen=True)
class ChannelData:
    """One simulated transmission: receiver samples + ground-truth symbols.

    ``rx`` has ``N_os`` samples per symbol and is aligned so that sample
    ``N_os * i`` corresponds to symbol ``i`` (timing recovery is assumed
    ideal, as in the paper's offline pipeline).
    """

    rx: np.ndarray  # float32 (n_sym * N_os,)
    symbols: np.ndarray  # float32 (n_sym,)  in {-1, +1}
    name: str


def prbs(n_sym: int, seed: int) -> np.ndarray:
    """Mersenne-Twister PAM-2 pseudo-random symbol sequence in {-1, +1}."""
    rng = np.random.RandomState(seed)  # MT19937, per the paper
    return (2.0 * rng.randint(0, 2, size=n_sym) - 1.0).astype(np.float32)


def rrc_taps(beta: float, span: int, sps: int) -> np.ndarray:
    """Root-raised-cosine filter taps (unit energy).

    ``span`` is the filter length in symbols, ``sps`` samples per symbol.
    """
    n = span * sps
    t = (np.arange(n) - n / 2.0) / sps  # time in symbol periods
    taps = np.zeros(n)
    for i, ti in enumerate(t):
        if abs(ti) < 1e-9:
            taps[i] = 1.0 - beta + 4.0 * beta / np.pi
        elif beta > 0 and abs(abs(4.0 * beta * ti) - 1.0) < 1e-9:
            taps[i] = (beta / np.sqrt(2.0)) * (
                (1.0 + 2.0 / np.pi) * np.sin(np.pi / (4.0 * beta))
                + (1.0 - 2.0 / np.pi) * np.cos(np.pi / (4.0 * beta))
            )
        else:
            num = np.sin(np.pi * ti * (1.0 - beta)) + 4.0 * beta * ti * np.cos(
                np.pi * ti * (1.0 + beta)
            )
            den = np.pi * ti * (1.0 - (4.0 * beta * ti) ** 2)
            taps[i] = num / den
    return (taps / np.sqrt(np.sum(taps**2))).astype(np.float64)


def rc_taps(beta: float, span: int, sps: int) -> np.ndarray:
    """Raised-cosine filter taps (used by the Proakis-B setup)."""
    n = span * sps
    t = (np.arange(n) - n / 2.0) / sps
    taps = np.sinc(t) * np.cos(np.pi * beta * t)
    den = 1.0 - (2.0 * beta * t) ** 2
    # L'Hopital at the singular points
    sing = np.abs(den) < 1e-9
    taps = np.where(sing, (np.pi / 4.0) * np.sinc(1.0 / (2.0 * beta)), taps / np.where(sing, 1.0, den))
    return (taps / np.max(np.abs(taps))).astype(np.float64)


def _cd_filter(n_fft: int, fs: float, length_km: float) -> np.ndarray:
    """Frequency response of chromatic dispersion over ``length_km``.

    All-pass: ``H(w) = exp(-j * beta2/2 * w^2 * L)`` with
    ``beta2 = -D lambda^2 / (2 pi c)``.
    """
    beta2 = -_D_CD * _LAMBDA**2 / (2.0 * np.pi * _C_LIGHT)
    freqs = np.fft.fftfreq(n_fft, d=1.0 / fs)
    w = 2.0 * np.pi * freqs
    return np.exp(-0.5j * beta2 * (length_km * 1e3) * w**2)


def _upsample(symbols: np.ndarray, sps: int) -> np.ndarray:
    up = np.zeros(len(symbols) * sps)
    up[::sps] = symbols
    return up


def imdd(
    n_sym: int,
    seed: int = 0,
    snr_db: float = 25.0,
    fiber_km: float = _FIBER_KM,
    rrc_beta: float = 0.2,
    rrc_span: int = 32,
    mod_index: float = 0.7,
) -> ChannelData:
    """Simulate the 40 GBd PAM-2 IM/DD link of Sec. 2.1.

    Pipeline: PRBS -> upsample (N_os) -> RRC -> MZM at quadrature
    (field = sqrt-intensity mapping linearized around the bias point)
    -> CD all-pass on the field -> photodiode ``|e|^2`` -> AWGN ->
    normalization.  Receiver noise is set by ``snr_db`` measured on the
    detected signal, matching the paper's "transceiver noise and CD
    remain as the impairing effects".
    """
    syms = prbs(n_sym, seed)
    fs = _BAUD * N_OS

    drive = np.convolve(_upsample(syms, N_OS), rrc_taps(rrc_beta, rrc_span, N_OS), "same")
    # MZM biased at quadrature: field amplitude cos(pi/4 * (1 - m*v)) —
    # keeps both the intensity modulation and the residual field
    # nonlinearity of a real modulator.  m scales the drive swing.
    m = mod_index
    field = np.cos(0.25 * np.pi * (1.0 - m * np.clip(drive, -1.5, 1.5)))
    # Chromatic dispersion acts on the optical field.
    field_disp = np.fft.ifft(np.fft.fft(field) * _cd_filter(len(field), fs, fiber_km))
    # Square-law detection: CD ∘ |.|^2 is the nonlinear composite.
    photo = np.abs(field_disp) ** 2
    photo = photo - photo.mean()
    photo = photo / photo.std()

    sig_pow = np.mean(photo**2)
    noise = np.random.RandomState(seed + 1).normal(
        0.0, np.sqrt(sig_pow / 10 ** (snr_db / 10.0)), size=photo.shape
    )
    rx = (photo + noise).astype(np.float32)
    # Align: RRC ("same" mode) keeps the symbol at sample N_os*i.
    return ChannelData(rx=rx, symbols=syms, name="imdd")


# Proakis-B impulse response (symbol-spaced), Sec. 2.2
H_PROAKIS_B = np.array([0.407, 0.815, 0.407])


def proakis_b(
    n_sym: int,
    seed: int = 0,
    snr_db: float = 20.0,
    rc_beta: float = 0.3,
    rc_span: int = 16,
) -> ChannelData:
    """Simulate the Proakis-B 'magnetic recording' channel of Sec. 2.2.

    Symbols -> RC pulse shaping (N_os = 2) -> T-spaced channel IR
    ``[0.407, 0.815, 0.407]`` -> AWGN at ``snr_db`` (paper models the
    bad-quality channel at 20 dB).
    """
    syms = prbs(n_sym, seed)
    shaped = np.convolve(_upsample(syms, N_OS), rc_taps(rc_beta, rc_span, N_OS), "same")
    # Upsample the T-spaced channel IR to the N_os grid (zeros between taps).
    h_up = np.zeros((len(H_PROAKIS_B) - 1) * N_OS + 1)
    h_up[::N_OS] = H_PROAKIS_B
    chan = np.convolve(shaped, h_up, "same")
    chan = chan / np.std(chan)

    sig_pow = np.mean(chan**2)
    noise = np.random.RandomState(seed + 1).normal(
        0.0, np.sqrt(sig_pow / 10 ** (snr_db / 10.0)), size=chan.shape
    )
    rx = (chan + noise).astype(np.float32)
    return ChannelData(rx=rx, symbols=syms, name="proakis_b")


def make_dataset(
    channel: str,
    n_sym: int,
    seed: int = 0,
    snr_db: float | None = None,
) -> ChannelData:
    """Dispatch helper used by train / dse / aot."""
    if channel == "imdd":
        return imdd(n_sym, seed=seed, snr_db=snr_db if snr_db is not None else 25.0)
    if channel in ("proakis", "proakis_b"):
        return proakis_b(n_sym, seed=seed, snr_db=snr_db if snr_db is not None else 20.0)
    raise ValueError(f"unknown channel {channel!r}")


def windows(
    data: ChannelData, seq_sym: int, stride_sym: int | None = None
) -> tuple[np.ndarray, np.ndarray]:
    """Cut a transmission into training windows.

    Returns ``(x, y)`` with ``x: (n, seq_sym * N_os)`` receiver samples
    and ``y: (n, seq_sym)`` transmitted symbols.
    """
    stride_sym = stride_sym or seq_sym
    n = (len(data.symbols) - seq_sym) // stride_sym + 1
    xs = np.stack(
        [data.rx[i * stride_sym * N_OS : i * stride_sym * N_OS + seq_sym * N_OS] for i in range(n)]
    )
    ys = np.stack([data.symbols[i * stride_sym : i * stride_sym + seq_sym] for i in range(n)])
    return xs.astype(np.float32), ys.astype(np.float32)
