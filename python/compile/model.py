"""L2 — the equalizer models as JAX computations.

Three model families, matching the paper's design-space exploration
(Sec. 3): the CNN equalizer built from the topology template of Fig. 1,
the linear FIR feed-forward equalizer (Sec. 3.2) and the order-3
Volterra equalizer (Sec. 3.3).

The CNN forward pass calls the L1 Pallas kernel
(:mod:`compile.kernels.conv1d`) for every convolutional layer; set
``EQ_USE_PALLAS=0`` to fall back to the pure-jnp oracle (useful for
fast training sweeps — identical numerics, checked by pytest).
"""

from __future__ import annotations

import dataclasses
import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import conv1d as pallas_conv1d
from .kernels import ref

N_OS = 2

Params = dict[str, Any]


def _use_pallas() -> bool:
    return os.environ.get("EQ_USE_PALLAS", "1") != "0"


def _conv(x, w, b, stride, padding, relu, use_pallas=None):
    if use_pallas if use_pallas is not None else _use_pallas():
        return pallas_conv1d.conv1d(x, w, b, stride, padding, relu=relu)
    return ref.conv1d(x, w, b, stride, padding, relu=relu)


# ---------------------------------------------------------------------------
# CNN equalizer (Fig. 1 topology template)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CnnConfig:
    """Topology template hyper-parameters (Sec. 3.1).

    ``L`` conv layers of kernel size ``K``; hidden feature maps have
    ``C`` channels; ``V_p`` symbols are produced per network pass.
    Strides: first layer ``V_p``, middle layers 1, last layer ``N_os``.
    """

    vp: int = 8
    layers: int = 3
    kernel: int = 9
    channels: int = 5
    n_os: int = N_OS

    @property
    def padding(self) -> int:
        return (self.kernel - 1) // 2

    def mac_per_symbol(self) -> float:
        """Average MAC operations per equalized symbol (paper's formula)."""
        k, c, l, vp = self.kernel, self.channels, self.layers, self.vp
        return k * c / vp + (l - 2) * k * c * c / vp + k * c / self.n_os

    def receptive_field_symbols(self) -> int:
        """Overlap symbols needed at each border (Sec. 6.1, o_sym)."""
        return (self.kernel - 1) * (1 + self.vp * (self.layers - 1)) // 2

    def out_symbols(self, in_samples: int) -> int:
        """Symbols produced for an input of ``in_samples`` samples."""
        w = in_samples
        for stride in self.strides():
            w = (w + 2 * self.padding - self.kernel) // stride + 1
        return w * self.vp

    def strides(self) -> list[int]:
        return [self.vp] + [1] * (self.layers - 2) + [self.n_os]

    def layer_channels(self) -> list[tuple[int, int]]:
        """(C_in, C_out) per layer: 1 -> C -> ... -> C -> V_p."""
        chans = [1] + [self.channels] * (self.layers - 1)
        outs = [self.channels] * (self.layers - 1) + [self.vp]
        return list(zip(chans, outs))


SELECTED = CnnConfig(vp=8, layers=3, kernel=9, channels=5)
"""The model chosen by the paper's DSE (Fig. 3): V_p=8, L=3, K=9, C=5."""


def cnn_init(cfg: CnnConfig, key: jax.Array) -> Params:
    """He-initialized parameters + BatchNorm state for the template."""
    params: Params = {"cfg": dataclasses.asdict(cfg)}
    for li, (cin, cout) in enumerate(cfg.layer_channels()):
        key, sub = jax.random.split(key)
        fan_in = cin * cfg.kernel
        params[f"w{li}"] = jax.random.normal(sub, (cout, cin, cfg.kernel)) * np.sqrt(
            2.0 / fan_in
        )
        params[f"b{li}"] = jnp.zeros((cout,))
        if li < cfg.layers - 1:  # BN after every layer but the last
            params[f"bn{li}_gamma"] = jnp.ones((cout,))
            params[f"bn{li}_beta"] = jnp.zeros((cout,))
    return params


def cnn_bn_state(cfg: CnnConfig) -> Params:
    state: Params = {}
    for li, (_, cout) in enumerate(cfg.layer_channels()[:-1]):
        state[f"bn{li}_mean"] = jnp.zeros((cout,))
        state[f"bn{li}_var"] = jnp.ones((cout,))
    return state


def cnn_forward_batch(
    params: Params,
    state: Params,
    xb: jnp.ndarray,
    cfg: CnnConfig,
    train: bool = False,
    momentum: float = 0.1,
    quant: Params | None = None,
    use_pallas: bool | None = None,
) -> tuple[jnp.ndarray, Params]:
    """Batched forward pass ``xb: (B, W)`` -> symbols ``(B, W//N_os)``.

    Training always passes ``use_pallas=False``: the Pallas interpret
    path has no reverse-mode AD rule, and the oracle is numerically
    identical (pytest-enforced).  Inference/export defaults to the env
    switch ``EQ_USE_PALLAS``.

    BatchNorm statistics are taken over (batch, width) — the paper's
    software training setup — with running averages maintained in
    ``state`` for inference.  ``quant`` optionally carries per-tensor
    bit widths ``{f"w{li}": (int_bits, frac_bits), f"a{li}": ..,
    "a_in": ..}`` for quantization-aware evaluation (``ref.fake_quant``
    — differentiable in the widths).
    """
    feat = xb[:, None, :]  # (B, 1, W)
    new_state = dict(state)
    strides = cfg.strides()

    def maybe_q(t, key_):
        if quant is None or key_ not in quant:
            return t
        ib, fb = quant[key_]
        return ref.fake_quant(t, ib, fb)

    conv_b = jax.vmap(
        lambda f, w_, b_, s_, p_, r_: _conv(f, w_, b_, s_, p_, r_, use_pallas=use_pallas),
        in_axes=(0, None, None, None, None, None),
    )

    feat = maybe_q(feat, "a_in")
    for li in range(cfg.layers):
        last = li == cfg.layers - 1
        w = maybe_q(params[f"w{li}"], f"w{li}")
        b = maybe_q(params[f"b{li}"], f"w{li}")
        feat = conv_b(feat, w, b, strides[li], cfg.padding, False)
        if not last:
            if train:
                mean = jnp.mean(feat, axis=(0, 2))
                var = jnp.var(feat, axis=(0, 2))
                new_state[f"bn{li}_mean"] = (
                    (1 - momentum) * state[f"bn{li}_mean"] + momentum * mean
                )
                new_state[f"bn{li}_var"] = (
                    (1 - momentum) * state[f"bn{li}_var"] + momentum * var
                )
            else:
                mean = state[f"bn{li}_mean"]
                var = state[f"bn{li}_var"]
            feat = (feat - mean[None, :, None]) / jnp.sqrt(var[None, :, None] + 1e-5)
            feat = (
                feat * params[f"bn{li}_gamma"][None, :, None]
                + params[f"bn{li}_beta"][None, :, None]
            )
            feat = jnp.maximum(feat, 0.0)
        feat = maybe_q(feat, f"a{li}")

    # (B, V_p, W_last) -> interleave channels: column j carries symbols
    # j*V_p .. j*V_p+V_p-1 (Fig. 1: flatten so each element is a symbol).
    return jnp.transpose(feat, (0, 2, 1)).reshape(feat.shape[0], -1), new_state


def cnn_forward(
    params: Params,
    state: Params,
    x: jnp.ndarray,
    cfg: CnnConfig,
    train: bool = False,
    momentum: float = 0.1,
    quant: Params | None = None,
    use_pallas: bool | None = None,
) -> tuple[jnp.ndarray, Params]:
    """Single-sequence wrapper of :func:`cnn_forward_batch` (``x: (W,)``)."""
    out, new_state = cnn_forward_batch(
        params,
        state,
        x[None, :],
        cfg,
        train=train,
        momentum=momentum,
        quant=quant,
        use_pallas=use_pallas,
    )
    return out[0], new_state


def cnn_fold_bn(params: Params, state: Params, cfg: CnnConfig) -> Params:
    """Fold BatchNorm scale/shift into conv weights for inference.

    This is what the FPGA datapath executes (one MAC array per layer, no
    separate normalization stage): w' = w * g / sqrt(v + eps),
    b' = (b - m) * g / sqrt(v + eps) + beta.
    """
    folded: Params = {"cfg": params.get("cfg")}
    for li in range(cfg.layers):
        w, b = params[f"w{li}"], params[f"b{li}"]
        if li < cfg.layers - 1:
            g = params[f"bn{li}_gamma"]
            beta = params[f"bn{li}_beta"]
            m = state[f"bn{li}_mean"]
            v = state[f"bn{li}_var"]
            scale = g / jnp.sqrt(v + 1e-5)
            w = w * scale[:, None, None]
            b = (b - m) * scale + beta
        folded[f"w{li}"] = w
        folded[f"b{li}"] = b
    return folded


def cnn_forward_folded(
    params: Params,
    x: jnp.ndarray,
    cfg: CnnConfig,
    quant_bits: dict[str, tuple[int, int]] | None = None,
) -> jnp.ndarray:
    """Inference pass with BN folded (the exported / FPGA graph).

    ``quant_bits`` applies static integer Q(m.n) fake quantization —
    the exact arithmetic the Rust fixed-point datapath mirrors
    bit-for-bit.  The export uses ``ref.fake_quant`` (numerically
    identical to the Pallas quant kernel, pytest-enforced): the old
    xla_extension 0.5.1 runtime crashes on modules containing multiple
    Pallas-lowered call graphs from the same kernel, and the ref
    formulation lowers to plain elementwise HLO.
    """
    feat = x[None, :]
    strides = cfg.strides()

    def maybe_q(t, key_):
        if quant_bits is None or key_ not in quant_bits:
            return t
        ib, fb = quant_bits[key_]
        return ref.fake_quant(t, float(int(ib)), float(int(fb)))

    feat = maybe_q(feat, "a_in")
    for li in range(cfg.layers):
        last = li == cfg.layers - 1
        w = maybe_q(params[f"w{li}"], f"w{li}")
        b = maybe_q(params[f"b{li}"], f"w{li}")
        feat = _conv(feat, w, b, strides[li], cfg.padding, relu=not last)
        feat = maybe_q(feat, f"a{li}")
    return feat.T.reshape(-1)


# ---------------------------------------------------------------------------
# Linear FIR equalizer (Sec. 3.2)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FirConfig:
    taps: int = 25
    n_os: int = N_OS

    def mac_per_symbol(self) -> float:
        # M MACs per output sample; every N_os-th sample is a symbol,
        # but only symbol-position outputs need computing -> M per symbol
        # ... the paper counts MACs to calculate one output *symbol*.
        return float(self.taps)


def fir_init(cfg: FirConfig, key: jax.Array) -> Params:
    w = jnp.zeros((cfg.taps,)).at[cfg.taps // 2].set(1.0)
    w = w + 0.01 * jax.random.normal(key, (cfg.taps,))
    return {"w": w}


def fir_forward(params: Params, x: jnp.ndarray, cfg: FirConfig) -> jnp.ndarray:
    """Equalize samples then decimate to symbol rate (Eq. 1)."""
    y = ref.fir(x, params["w"])
    return y[:: cfg.n_os]


# ---------------------------------------------------------------------------
# Volterra equalizer (Sec. 3.3)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class VolterraConfig:
    m1: int = 25
    m2: int = 9
    m3: int = 3
    n_os: int = N_OS

    def mac_per_symbol(self) -> float:
        return float(self.m1 + self.m2**2 + self.m3**3)


def volterra_init(cfg: VolterraConfig, key: jax.Array) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    w1 = jnp.zeros((cfg.m1,)).at[cfg.m1 // 2].set(1.0)
    return {
        "w0": jnp.zeros(()),
        "w1": w1 + 0.01 * jax.random.normal(k1, (cfg.m1,)),
        "w2": 0.001 * jax.random.normal(k2, (cfg.m2, cfg.m2)),
        "w3": 0.0001 * jax.random.normal(k3, (cfg.m3, cfg.m3, cfg.m3)),
    }


def volterra_forward(params: Params, x: jnp.ndarray, cfg: VolterraConfig) -> jnp.ndarray:
    y = ref.volterra(x, params["w0"], params["w1"], params["w2"], params["w3"])
    return y[:: cfg.n_os]
