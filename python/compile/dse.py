"""Design-space exploration framework (Sec. 3.4 / 3.5, Figs. 2 and 4).

Sweeps the three equalizer families over the paper's grids, training
each configuration and recording (MAC/symbol, BER).  Results are
written as JSON to ``artifacts/`` where the Rust side
(``rust/src/dse``) computes Pareto fronts, applies the hardware-aware
complexity ceiling and renders the figure tables.

The paper trains 135 CNN configurations x 3 seeds x 10k iterations on a
GPU; on this CPU-only image the default budget is scaled down
(``--iters``, ``--seeds``); ``--full`` restores the paper's grid and
budget.  The *shape* of Fig. 2 (CNN Pareto front dominating FIR below
BER ~1e-2, FIR saturating, Volterra in between) is what the scaled run
must reproduce — see DESIGN.md §6.

Usage:
  python -m compile.dse --channel imdd --out ../artifacts/dse_imdd.json
  python -m compile.dse --channel proakis --out ../artifacts/dse_proakis.json
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

from . import channels, model, train

# Paper grids (Sec. 3.5)
FULL_VP = [1, 2, 4, 8, 16]
FULL_L = [3, 4, 5]
FULL_K = [9, 15, 21]
FULL_C = [3, 4, 5]
FULL_FIR_TAPS = [3, 5, 9, 17, 25, 41, 57, 89, 121, 185, 249, 377, 505, 761, 1017]
FULL_VOLTERRA = [
    (m1, m2, m3)
    for m1 in [3, 9, 15, 25, 35, 55, 75, 89, 121]
    for m2 in [1, 3, 9, 15, 25, 30, 35]
    for m3 in [1, 3, 9, 15]
]

# Scaled grids: the Pareto-relevant corner of each family.
FAST_VP = [1, 2, 4, 8, 16]
FAST_L = [3, 4, 5]
FAST_K = [9, 15, 21]
FAST_C = [3, 4, 5]
FAST_FIR_TAPS = [3, 5, 9, 17, 25, 41, 57, 89, 121, 185]
FAST_VOLTERRA = [
    (3, 1, 1), (9, 3, 1), (15, 3, 3), (25, 9, 3), (35, 9, 3),
    (25, 15, 3), (35, 15, 9), (55, 15, 9), (55, 25, 9), (75, 25, 15),
]


def run_dse(
    channel: str,
    iters: int,
    seeds: int,
    full: bool,
    n_sym: int,
    snr_db: float | None,
    families: list[str],
) -> dict:
    data = channels.make_dataset(channel, n_sym, seed=0, snr_db=snr_db)
    eval_data = channels.make_dataset(channel, n_sym // 2, seed=1000, snr_db=snr_db)
    results = []
    t0 = time.time()

    def record(family, cfg_dict, mac, bers, secs):
        # Paper: keep the *highest* BER of the training repetitions
        # (pessimistic selection, Sec. 3.4).
        results.append(
            {
                "family": family,
                "config": cfg_dict,
                "mac_per_symbol": mac,
                "ber": max(bers),
                "ber_runs": bers,
                "train_seconds": secs,
            }
        )
        print(
            f"[{time.time()-t0:7.1f}s] {family:8s} {cfg_dict} mac={mac:8.1f} "
            f"ber={max(bers):.3e}"
        )

    if "cnn" in families:
        grid_vp, grid_l, grid_k, grid_c = (
            (FULL_VP, FULL_L, FULL_K, FULL_C) if full else (FAST_VP, FAST_L, FAST_K, FAST_C)
        )
        for vp in grid_vp:
            for l in grid_l:
                for k in grid_k:
                    for c in grid_c:
                        cfg = model.CnnConfig(vp=vp, layers=l, kernel=k, channels=c)
                        t1, bers = time.time(), []
                        for s in range(seeds):
                            r = train.train_cnn(
                                cfg, data, iters=iters, seed=s, eval_data=eval_data
                            )
                            bers.append(r.ber)
                        record(
                            "cnn",
                            dataclasses.asdict(cfg),
                            cfg.mac_per_symbol(),
                            bers,
                            time.time() - t1,
                        )

    if "fir" in families:
        for taps in FULL_FIR_TAPS if full else FAST_FIR_TAPS:
            cfg = model.FirConfig(taps=taps)
            t1, bers = time.time(), []
            for s in range(seeds):
                r = train.train_fir(cfg, data, iters=iters, seed=s, eval_data=eval_data)
                bers.append(r.ber)
            record("fir", dataclasses.asdict(cfg), cfg.mac_per_symbol(), bers, time.time() - t1)

    if "volterra" in families:
        for m1, m2, m3 in FULL_VOLTERRA if full else FAST_VOLTERRA:
            cfg = model.VolterraConfig(m1=m1, m2=m2, m3=m3)
            t1, bers = time.time(), []
            for s in range(seeds):
                r = train.train_volterra(cfg, data, iters=iters, seed=s, eval_data=eval_data)
                bers.append(r.ber)
            record(
                "volterra", dataclasses.asdict(cfg), cfg.mac_per_symbol(), bers, time.time() - t1
            )

    return {
        "channel": channel,
        "iters": iters,
        "seeds": seeds,
        "full": full,
        "results": results,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--channel", default="imdd", choices=["imdd", "proakis"])
    ap.add_argument("--out", default=None)
    ap.add_argument("--iters", type=int, default=600)
    ap.add_argument("--seeds", type=int, default=1)
    ap.add_argument("--n-sym", type=int, default=60_000)
    ap.add_argument("--snr-db", type=float, default=None)
    ap.add_argument("--full", action="store_true", help="paper-scale grid and budget")
    ap.add_argument(
        "--families",
        default="cnn,fir,volterra",
        help="comma-separated subset of {cnn,fir,volterra}",
    )
    args = ap.parse_args()
    if args.full:
        args.iters = max(args.iters, 10_000)
        args.seeds = max(args.seeds, 3)

    # The sweep only needs training throughput; the jnp oracle is
    # numerically identical to the Pallas kernel (pytest-enforced) and
    # much faster under jit on CPU.
    os.environ.setdefault("EQ_USE_PALLAS", "0")

    out = args.out or f"../artifacts/dse_{args.channel}.json"
    res = run_dse(
        args.channel,
        args.iters,
        args.seeds,
        args.full,
        args.n_sym,
        args.snr_db,
        [f.strip() for f in args.families.split(",")],
    )
    os.makedirs(os.path.dirname(os.path.abspath(out)), exist_ok=True)
    with open(out, "w") as f:
        json.dump(res, f, indent=1)
    print(f"wrote {len(res['results'])} results to {out}")


if __name__ == "__main__":
    main()
