"""Build-time training: MSE + Adam, as in the paper (Sec. 3.4).

optax is not available in this image, so Adam is implemented directly;
it is the textbook algorithm (Kingma & Ba) with bias correction, which
is also what the paper uses for all three equalizer families.

All training happens at build time (``make artifacts`` / the DSE
sweeps); nothing here ever runs on the Rust request path.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from . import channels, model

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# Hand-rolled Adam
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class AdamState:
    m: Params
    v: Params
    step: int


def adam_init(params: Params) -> AdamState:
    zeros = jax.tree.map(jnp.zeros_like, params)
    return AdamState(m=zeros, v=jax.tree.map(jnp.zeros_like, params), step=0)


def adam_update(
    params: Params,
    grads: Params,
    state: AdamState,
    lr: float = 1e-3,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
) -> tuple[Params, AdamState]:
    step = state.step + 1
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state.m, grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state.v, grads)
    mhat_scale = 1.0 / (1 - b1**step)
    vhat_scale = 1.0 / (1 - b2**step)
    new = jax.tree.map(
        lambda p, m_, v_: p - lr * (m_ * mhat_scale) / (jnp.sqrt(v_ * vhat_scale) + eps),
        params,
        m,
        v,
    )
    return new, AdamState(m=m, v=v, step=step)


# ---------------------------------------------------------------------------
# Metrics
# ---------------------------------------------------------------------------


def ber(pred_sym: np.ndarray, true_sym: np.ndarray) -> float:
    """Bit error ratio for PAM-2 after nearest-symbol decision (sign)."""
    dec = np.where(np.asarray(pred_sym) >= 0.0, 1.0, -1.0)
    return float(np.mean(dec != np.asarray(true_sym)))


# ---------------------------------------------------------------------------
# Training loops
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class TrainResult:
    params: Params
    bn_state: Params
    ber: float
    loss_curve: list[float]


def _batches(x: np.ndarray, y: np.ndarray, batch: int, seed: int):
    rng = np.random.RandomState(seed)
    n = x.shape[0]
    while True:
        idx = rng.randint(0, n, size=batch)
        yield x[idx], y[idx]


def train_cnn(
    cfg: model.CnnConfig,
    data: channels.ChannelData,
    iters: int = 4000,
    batch: int = 64,
    seq_sym: int = 128,
    lr: float = 1e-3,
    seed: int = 0,
    eval_data: channels.ChannelData | None = None,
) -> TrainResult:
    """Supervised MSE training of the CNN template on one channel."""
    x_all, y_all = channels.windows(data, seq_sym)
    params = model.cnn_init(cfg, jax.random.PRNGKey(seed))
    bn_state = model.cnn_bn_state(cfg)
    cfg_meta = params.pop("cfg")
    opt = adam_init(params)

    def loss_fn(p, s, xb, yb):
        pred, new_s = model.cnn_forward_batch(p, s, xb, cfg, train=True, use_pallas=False)
        return jnp.mean((pred - yb) ** 2), new_s

    @jax.jit
    def step(p, s, o_m, o_v, o_t, xb, yb):
        (loss, new_s), grads = jax.value_and_grad(loss_fn, has_aux=True)(p, s, xb, yb)
        new_p, new_opt = adam_update(p, grads, AdamState(o_m, o_v, o_t), lr=lr)
        return new_p, new_s, new_opt.m, new_opt.v, new_opt.step, loss

    curve: list[float] = []
    gen = _batches(x_all, y_all, batch, seed)
    m, v, t = opt.m, opt.v, opt.step
    for it in range(iters):
        xb, yb = next(gen)
        params, bn_state, m, v, t, loss = step(params, bn_state, m, v, t, xb, yb)
        if it % 50 == 0:
            curve.append(float(loss))

    ev = eval_data or data
    b = eval_cnn(params, bn_state, cfg, ev)
    params["cfg"] = cfg_meta
    return TrainResult(params=params, bn_state=bn_state, ber=b, loss_curve=curve)


def eval_cnn(
    params: Params,
    bn_state: Params,
    cfg: model.CnnConfig,
    data: channels.ChannelData,
    seq_sym: int = 512,
) -> float:
    p = {k: v for k, v in params.items() if k != "cfg"}
    x_all, y_all = channels.windows(data, seq_sym)

    @jax.jit
    def fwd(xb):
        return model.cnn_forward_batch(p, bn_state, xb, cfg, train=False, use_pallas=False)[0]

    preds = np.asarray(fwd(jnp.asarray(x_all)))
    # Discard half a receptive field at each border (the coordinator's
    # OGM/ORM does the same on the Rust side).
    o = min(cfg.receptive_field_symbols(), preds.shape[1] // 4)
    return ber(preds[:, o:-o or None].reshape(-1), y_all[:, o:-o or None].reshape(-1))


def train_fir(
    cfg: model.FirConfig,
    data: channels.ChannelData,
    iters: int = 1500,
    batch: int = 32,
    seq_sym: int = 128,
    lr: float = 2e-3,
    seed: int = 0,
    eval_data: channels.ChannelData | None = None,
) -> TrainResult:
    """MSE/Adam training of the linear equalizer (Sec. 3.2)."""
    x_all, y_all = channels.windows(data, seq_sym)
    params = model.fir_init(cfg, jax.random.PRNGKey(seed))
    opt = adam_init(params)

    def loss_fn(p, xb, yb):
        pred = jax.vmap(lambda x: model.fir_forward(p, x, cfg))(xb)
        return jnp.mean((pred - yb) ** 2)

    @jax.jit
    def step(p, o_m, o_v, o_t, xb, yb):
        loss, grads = jax.value_and_grad(loss_fn)(p, xb, yb)
        new_p, new_opt = adam_update(p, grads, AdamState(o_m, o_v, o_t), lr=lr)
        return new_p, new_opt.m, new_opt.v, new_opt.step, loss

    curve: list[float] = []
    gen = _batches(x_all, y_all, batch, seed)
    m, v, t = opt.m, opt.v, opt.step
    for it in range(iters):
        xb, yb = next(gen)
        params, m, v, t, loss = step(params, m, v, t, xb, yb)
        if it % 50 == 0:
            curve.append(float(loss))

    ev = eval_data or data
    b = eval_generic(lambda x: model.fir_forward(params, x, cfg), cfg.taps // (2 * 2) + 1, ev)
    return TrainResult(params=params, bn_state={}, ber=b, loss_curve=curve)


def train_volterra(
    cfg: model.VolterraConfig,
    data: channels.ChannelData,
    iters: int = 1500,
    batch: int = 32,
    seq_sym: int = 128,
    lr: float = 2e-3,
    seed: int = 0,
    eval_data: channels.ChannelData | None = None,
) -> TrainResult:
    """MSE/Adam training of the order-3 Volterra equalizer (Sec. 3.3)."""
    x_all, y_all = channels.windows(data, seq_sym)
    params = model.volterra_init(cfg, jax.random.PRNGKey(seed))
    opt = adam_init(params)

    def loss_fn(p, xb, yb):
        pred = jax.vmap(lambda x: model.volterra_forward(p, x, cfg))(xb)
        return jnp.mean((pred - yb) ** 2)

    @jax.jit
    def step(p, o_m, o_v, o_t, xb, yb):
        loss, grads = jax.value_and_grad(loss_fn)(p, xb, yb)
        new_p, new_opt = adam_update(p, grads, AdamState(o_m, o_v, o_t), lr=lr)
        return new_p, new_opt.m, new_opt.v, new_opt.step, loss

    curve: list[float] = []
    gen = _batches(x_all, y_all, batch, seed)
    m, v, t = opt.m, opt.v, opt.step
    for it in range(iters):
        xb, yb = next(gen)
        params, m, v, t, loss = step(params, m, v, t, xb, yb)
        if it % 50 == 0:
            curve.append(float(loss))

    ev = eval_data or data
    half = max(cfg.m1, cfg.m2, cfg.m3) // (2 * 2) + 1
    b = eval_generic(lambda x: model.volterra_forward(params, x, cfg), half, ev)
    return TrainResult(params=params, bn_state={}, ber=b, loss_curve=curve)


def eval_generic(
    fwd: Callable[[jnp.ndarray], jnp.ndarray],
    border_sym: int,
    data: channels.ChannelData,
    seq_sym: int = 512,
) -> float:
    x_all, y_all = channels.windows(data, seq_sym)
    f = jax.jit(jax.vmap(fwd))
    preds = np.asarray(f(jnp.asarray(x_all)))
    o = min(border_sym, preds.shape[1] // 4)
    return ber(preds[:, o:-o or None].reshape(-1), y_all[:, o:-o or None].reshape(-1))
