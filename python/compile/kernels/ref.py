"""Pure-jnp oracles for the Pallas kernels.

These are the correctness references: every Pallas kernel in this
package must match its oracle to float tolerance (pytest + hypothesis
sweeps in ``python/tests/test_kernel.py``).  They are also what the JAX
model falls back to when ``EQ_USE_PALLAS=0``.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def round_ties_even(v: jnp.ndarray) -> jnp.ndarray:
    """Round half to even, built from floor/where only.

    Numerically identical to ``jnp.round``, but ``jnp.round`` lowers to
    the ``round-nearest-even`` HLO op which the xla_extension 0.5.1
    runtime (the Rust PJRT client) does not implement — it raises a C++
    exception at compile time.  floor/select lower to universally
    supported ops, so this form is safe to bake into artifacts.
    """
    f = jnp.floor(v)
    d = v - f
    r = jnp.floor(v + 0.5)
    # Exact .5 ties go to the even neighbour: f if f even, else f + 1.
    r_tie = f + jnp.mod(f, 2.0)
    return jnp.where(d == 0.5, r_tie, r)


def conv1d(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: jnp.ndarray,
    stride: int,
    padding: int,
    relu: bool = False,
) -> jnp.ndarray:
    """Strided, padded 1-D convolution (cross-correlation).

    Args:
      x: ``(C_in, W)`` input feature map.
      w: ``(C_out, C_in, K)`` kernel.
      b: ``(C_out,)`` bias.
      stride: output stride.
      padding: symmetric zero padding on the width axis.
      relu: fuse a ReLU on the output.

    Returns:
      ``(C_out, W_out)`` with ``W_out = (W + 2*padding - K)//stride + 1``.
    """
    out = lax.conv_general_dilated(
        x[None],  # (1, C_in, W)
        w,  # (C_out, C_in, K)
        window_strides=(stride,),
        padding=[(padding, padding)],
        dimension_numbers=("NCH", "OIH", "NCH"),
    )[0] + b[:, None]
    return jnp.maximum(out, 0.0) if relu else out


def fake_quant(x: jnp.ndarray, int_bits: float, frac_bits: float) -> jnp.ndarray:
    """Fixed-point fake quantization to Q(int_bits.frac_bits), signed.

    Rounds to the nearest representable value and saturates at the
    format's range — the arithmetic the FPGA datapath performs (Sec. 4).
    Bit widths may be fractional: the value is the linear interpolation
    between the two adjacent integer-width quantizations, which is what
    makes the bit widths trainable (the paper's differentiable
    interpolation).
    """

    def q(i, f):
        scale = 2.0**f
        lo = -(2.0 ** (i - 1.0))
        hi = 2.0 ** (i - 1.0) - 1.0 / scale
        return jnp.clip(round_ties_even(x * scale) / scale, lo, hi)

    i0, f0 = jnp.floor(int_bits), jnp.floor(frac_bits)
    wi, wf = int_bits - i0, frac_bits - f0
    # Bilinear interpolation across the four adjacent integer formats.
    return (
        (1 - wi) * (1 - wf) * q(i0, f0)
        + (1 - wi) * wf * q(i0, f0 + 1)
        + wi * (1 - wf) * q(i0 + 1, f0)
        + wi * wf * q(i0 + 1, f0 + 1)
    )


def fir(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Linear feed-forward equalizer, Eq. (1): centered FIR of M taps.

    ``x: (W,)`` samples, ``w: (M,)`` taps -> ``(W,)`` output (same
    length; zero-padded borders).
    """
    m = w.shape[0]
    return conv1d(x[None], w[None, None, :], jnp.zeros((1,)), 1, (m - 1) // 2)[0][
        : x.shape[0]
    ]


def volterra(
    x: jnp.ndarray,
    w0: jnp.ndarray,
    w1: jnp.ndarray,
    w2: jnp.ndarray,
    w3: jnp.ndarray,
) -> jnp.ndarray:
    """Order-3 Volterra equalizer (Sec. 3.3), evaluated per output sample.

    ``w1: (M1,)``, ``w2: (M2, M2)``, ``w3: (M3, M3, M3)``.  Memory
    windows are centered; borders are zero-padded.  Pass size-1
    all-zero kernels to disable an order (paper's ``M_p = 1`` case).
    """
    n = x.shape[0]

    def win(m):
        half = m // 2
        xp = jnp.pad(x, (half, half))
        idx = jnp.arange(n)[:, None] + jnp.arange(m)[None, :]
        return xp[idx]  # (n, m)

    y = jnp.full((n,), w0)
    x1 = win(w1.shape[0])
    y = y + x1 @ w1
    x2 = win(w2.shape[0])
    y = y + jnp.einsum("na,nb,ab->n", x2, x2, w2)
    x3 = win(w3.shape[0])
    y = y + jnp.einsum("na,nb,nc,abc->n", x3, x3, x3, w3)
    return y
