"""Pallas fixed-point fake-quantization kernel.

Element-wise quantize-to-Q(m.n): round to ``frac_bits`` fractional bits
and saturate to the signed range of ``int_bits`` integer bits.  This is
the numeric behaviour of the FPGA datapath (Sec. 4): values live in
fixed-point format with independent integer/fraction widths per tensor.

The *trainable* (fractional-bit-width, interpolated) variant used by
quantization-aware training lives in ``ref.fake_quant`` — bit widths are
traced there.  This kernel is the inference-path version with static
integer widths; it is what ``aot.py`` bakes into the exported HLO of the
quantized model.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import round_ties_even


def _quant_kernel(x_ref, o_ref, *, scale, lo, hi):
    x = x_ref[...]
    # round_ties_even, not jnp.round: the round-nearest-even HLO op is
    # rejected by the Rust runtime's XLA 0.5.1 (see ref.round_ties_even).
    o_ref[...] = jnp.clip(round_ties_even(x * scale) / scale, lo, hi)


@functools.partial(jax.jit, static_argnames=("int_bits", "frac_bits"))
def fake_quant(x: jnp.ndarray, int_bits: int, frac_bits: int) -> jnp.ndarray:
    """Quantize ``x`` to signed Q(int_bits.frac_bits) fixed point.

    Matches ``ref.fake_quant`` exactly when the widths are integers.
    """
    scale = float(2.0**frac_bits)
    lo = -float(2.0 ** (int_bits - 1))
    hi = float(2.0 ** (int_bits - 1)) - 1.0 / scale
    flat = x.reshape(-1)
    # Pad to a lane-friendly multiple; element-wise so padding is inert.
    n = flat.shape[0]
    tile = 1024
    n_pad = -(-n // tile) * tile
    flat = jnp.pad(flat, (0, n_pad - n))
    out = pl.pallas_call(
        functools.partial(_quant_kernel, scale=scale, lo=lo, hi=hi),
        grid=(n_pad // tile,),
        in_specs=[pl.BlockSpec((tile,), lambda i: (i,))],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), jnp.float32),
        interpret=True,
    )(flat)
    return out[:n].reshape(x.shape)
