"""Pallas strided 1-D convolution — the L1 compute hot-spot.

Every layer of the equalizer CNN is one call of this kernel, so the
whole network lowers to a chain of these plus element-wise glue.

Hardware adaptation (DESIGN.md §2): the paper's FPGA datapath unrolls
the kernel (K), input-channel (I_c) and output-channel (O_c) loops into
a spatial MAC array producing one output group per clock.  On a TPU the
same insight — keep all short axes resident, feed a matrix unit — maps
to an im2col formulation: each grid step materializes a
``(TILE, K * C_in)`` patch matrix in VMEM and multiplies it against the
``(K * C_in, C_out)`` weight matrix on the MXU.  The sequence axis is
tiled by the grid (the analogue of the paper's streaming pipeline); the
input signal is kept VMEM-resident because BlockSpec cannot express the
overlapping strided windows directly (receptive fields of adjacent
tiles overlap by ``K - stride`` samples).  For the paper's topology
(C <= 5, K = 9, sub-sequences of a few thousand samples) the resident
signal is tens of KiB — far below the ~16 MiB VMEM budget; the VMEM
footprint analysis lives in DESIGN.md §7 and EXPERIMENTS.md §Perf.

``interpret=True`` always: the CPU PJRT plugin cannot execute Mosaic
custom calls; interpret mode lowers to plain HLO which the Rust runtime
(xla crate, PJRT CPU) executes directly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default number of output positions computed per grid step.  128 keeps
# the patch matrix MXU-shaped ((128, K*C_in) x (K*C_in, C_out)).
DEFAULT_TILE = 128


def _conv1d_kernel(x_ref, w_ref, b_ref, o_ref, *, stride, k, tile, relu):
    """One grid step: produce ``(C_out, tile)`` output positions.

    ``x_ref`` holds the whole (already zero-padded) input ``(C_in, Wp)``;
    ``w_ref`` is ``(C_out, C_in, K)``; ``o_ref`` is the ``(C_out, tile)``
    output block for this step.
    """
    ti = pl.program_id(0)
    span = (tile - 1) * stride + k
    # Receptive field of this output tile: [ti*tile*stride, ... + span).
    xblk = pl.load(x_ref, (slice(None), pl.ds(ti * tile * stride, span)))

    # im2col: (C_in, tile, K) gather -> (tile, C_in*K) patch matrix.
    pos = jnp.arange(tile)[:, None] * stride + jnp.arange(k)[None, :]
    patches = jnp.transpose(xblk[:, pos], (1, 0, 2)).reshape(tile, -1)

    # (C_out, C_in, K) -> (C_in*K, C_out): the MXU-side operand.
    wmat = jnp.transpose(w_ref[...], (1, 2, 0)).reshape(-1, o_ref.shape[0])

    out = jnp.dot(patches, wmat, preferred_element_type=jnp.float32)
    out = out + b_ref[...][None, :]
    if relu:
        out = jnp.maximum(out, 0.0)
    o_ref[...] = out.T


@functools.partial(jax.jit, static_argnames=("stride", "padding", "relu", "tile"))
def conv1d(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: jnp.ndarray,
    stride: int,
    padding: int,
    relu: bool = False,
    tile: int = DEFAULT_TILE,
) -> jnp.ndarray:
    """Strided padded 1-D convolution via the Pallas kernel.

    Same contract as :func:`compile.kernels.ref.conv1d` (the oracle):
    ``x (C_in, W)``, ``w (C_out, C_in, K)``, ``b (C_out,)`` ->
    ``(C_out, W_out)``.
    """
    c_in, width = x.shape
    c_out, c_in_w, k = w.shape
    assert c_in == c_in_w, (c_in, c_in_w)
    w_out = (width + 2 * padding - k) // stride + 1
    assert w_out >= 1, "input shorter than kernel"

    tile = min(tile, w_out)
    n_tiles = -(-w_out // tile)  # ceil
    w_out_pad = n_tiles * tile

    # Zero-pad: `padding` on the left; on the right enough for both the
    # convolution padding and the tile overshoot.
    span_last = ((w_out_pad - 1) * stride + k) - width - padding
    xp = jnp.pad(x, ((0, 0), (padding, max(span_last, padding))))

    out = pl.pallas_call(
        functools.partial(_conv1d_kernel, stride=stride, k=k, tile=tile, relu=relu),
        grid=(n_tiles,),
        in_specs=[
            # Whole padded signal resident (see module docstring).
            pl.BlockSpec(xp.shape, lambda i: (0, 0)),
            pl.BlockSpec(w.shape, lambda i: (0, 0, 0)),
            pl.BlockSpec(b.shape, lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((c_out, tile), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((c_out, w_out_pad), jnp.float32),
        interpret=True,
    )(xp, w, b)
    return out[:, :w_out]


def vmem_bytes(c_in: int, width: int, k: int, c_out: int, stride: int, tile: int = DEFAULT_TILE) -> int:
    """Static VMEM footprint estimate of one grid step (f32).

    Used by the perf analysis (EXPERIMENTS.md §Perf) — resident signal +
    weights + patch matrix + output block.
    """
    span = (tile - 1) * stride + k
    resident = c_in * (width + 2 * k) * 4
    weights = c_out * c_in * k * 4
    patches = tile * c_in * k * 4 + c_in * span * 4
    out = c_out * tile * 4
    return resident + weights + patches + out


def mxu_utilization(c_in: int, k: int, c_out: int, tile: int = DEFAULT_TILE) -> float:
    """Estimated MXU utilization of the im2col matmul.

    A 128x128 MXU tile performs 128*128*128 MACs per pass; the kernel's
    matmul is (tile, c_in*k) x (c_in*k, c_out).  Utilization is the
    fraction of the systolic array doing useful work (both contraction
    and output-channel axes are narrow for this topology — the paper's
    FPGA sidesteps this with a bespoke array; on TPU the roofline is
    bounded by these ratios).
    """
    kk = c_in * k
    return (min(tile, 128) / 128.0) * (min(kk, 128) / 128.0) * (min(c_out, 128) / 128.0)
