"""Automatic quantization: learnable per-layer bit widths (Sec. 4).

Follows the BitPruning-style approach the paper adapts [20]: the loss
is augmented with a bit-width penalty

    loss = MSE + QLF * (B_p + B_a) / 2

where ``B_p`` / ``B_a`` are the average bit widths of the trainable
parameters / activations, and each width is a *continuous* trainable
value made differentiable by interpolating between the adjacent
integer-width quantizations (``ref.fake_quant``).  Unlike [20], the
integer and fraction widths are learned *separately*, so the learned
format maps 1:1 onto the fixed-point hardware datapath (no runtime
scaling).

Training runs in the paper's three phases (Fig. 5/6):
  1. full-precision training (widths pinned at 16.16),
  2. bit-width-aware training (widths + weights trained jointly),
  3. fine-tuning (widths frozen at the next-highest integer).

Gradient flow: ``round`` is a.e. flat, so a straight-through estimator
carries the data gradient while the interpolation coefficients carry
the width gradient — ``fake_quant_ste`` below.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from . import channels, model, train
from .kernels import ref

Params = dict[str, Any]

BITS_MIN, BITS_MAX = 1.0, 16.0

# Captured before any monkeypatching (train_qat temporarily swaps
# ``ref.fake_quant`` for the STE variant so the model picks it up).
_FAKE_QUANT = ref.fake_quant


def fake_quant_ste(x: jnp.ndarray, int_bits, frac_bits) -> jnp.ndarray:
    """Interpolated fixed-point quantization with straight-through data grad.

    Numerically equals ``ref.fake_quant``; d/dx == 1 (STE), d/dbits flows
    through the interpolation coefficients.
    """
    y = _FAKE_QUANT(x, int_bits, frac_bits)
    return y + x - jax.lax.stop_gradient(x)


def clip_bits(b: jnp.ndarray) -> jnp.ndarray:
    return jnp.clip(b, BITS_MIN, BITS_MAX)


@dataclasses.dataclass
class QatResult:
    params: Params
    bn_state: Params
    bits: dict[str, tuple[int, int]]  # frozen integer widths per tensor
    history: list[dict]  # per-log-step: iter, phase, avg bits, ber
    ber: float


def init_bit_params(cfg: model.CnnConfig) -> Params:
    """One (int, frac) width pair per weight tensor and per activation."""
    bits: Params = {}
    for li in range(cfg.layers):
        bits[f"w{li}"] = jnp.array([16.0, 16.0])  # [int, frac]: paper starts 16.16
        bits[f"a{li}"] = jnp.array([16.0, 16.0])
    bits["a_in"] = jnp.array([16.0, 16.0])
    return bits


def _quant_spec(bits: Params) -> dict[str, tuple[jnp.ndarray, jnp.ndarray]]:
    return {k: (clip_bits(v)[0], clip_bits(v)[1]) for k, v in bits.items()}


def avg_bits(bits: Params, prefix: str) -> jnp.ndarray:
    vals = [jnp.sum(clip_bits(v)) for k, v in bits.items() if k.startswith(prefix)]
    return jnp.stack(vals).mean()


def frozen_bits(bits: Params) -> dict[str, tuple[int, int]]:
    """Phase-3 freeze: each width fixed to the next-highest integer."""
    out = {}
    for k, v in bits.items():
        b = np.asarray(clip_bits(v))
        out[k] = (int(np.ceil(b[0])), int(np.ceil(b[1])))
    return out


def train_qat(
    cfg: model.CnnConfig,
    data: channels.ChannelData,
    qlf: float = 5e-4,
    iters_fp: int = 800,
    iters_bits: int = 1200,
    iters_ft: int = 600,
    batch: int = 32,
    seq_sym: int = 128,
    lr: float = 1e-3,
    bits_lr: float = 0.05,
    seed: int = 0,
    eval_every: int = 100,
    eval_data: channels.ChannelData | None = None,
) -> QatResult:
    """Three-phase quantization-aware training of the CNN equalizer."""
    x_all, y_all = channels.windows(data, seq_sym)
    params = model.cnn_init(cfg, jax.random.PRNGKey(seed))
    cfg_meta = params.pop("cfg")
    bn_state = model.cnn_bn_state(cfg)
    bits = init_bit_params(cfg)
    ev = eval_data or data

    def loss_quant(p, bt, s, xb, yb, use_qlf):
        spec = _quant_spec(bt)
        pred, new_s = model.cnn_forward_batch(p, s, xb, cfg, train=True, quant=spec, use_pallas=False)
        mse = jnp.mean((pred - yb) ** 2)
        bp = avg_bits(bt, "w")
        ba = (avg_bits(bt, "a") * cfg.layers + jnp.sum(clip_bits(bt["a_in"]))) / (
            cfg.layers + 1
        )
        return mse + use_qlf * (bp + ba) / 2.0, new_s

    # Patch the model's quantizer to the STE variant for training.
    orig_fq = ref.fake_quant
    ref.fake_quant = fake_quant_ste  # type: ignore[assignment]
    try:
        history: list[dict] = []
        opt_p = train.adam_init(params)
        opt_b = train.adam_init(bits)

        @jax.jit
        def step_fp(p, s, om, ov, ot, xb, yb):
            def lf(p_):
                pred, new_s = model.cnn_forward_batch(p_, s, xb, cfg, train=True, use_pallas=False)
                return jnp.mean((pred - yb) ** 2), new_s

            (loss, new_s), g = jax.value_and_grad(lf, has_aux=True)(p)
            new_p, opt = train.adam_update(p, g, train.AdamState(om, ov, ot), lr=lr)
            return new_p, new_s, opt.m, opt.v, opt.step, loss

        @jax.jit
        def step_bits(p, bt, s, pm, pv, pt, bm, bv, bt_step, xb, yb):
            (loss, new_s), (gp, gb) = jax.value_and_grad(
                lambda p_, b_: loss_quant(p_, b_, s, xb, yb, qlf), argnums=(0, 1), has_aux=True
            )(p, bt)
            new_p, op = train.adam_update(p, gp, train.AdamState(pm, pv, pt), lr=lr)
            new_b, ob = train.adam_update(bt, gb, train.AdamState(bm, bv, bt_step), lr=bits_lr)
            return new_p, new_b, new_s, op.m, op.v, op.step, ob.m, ob.v, ob.step, loss

        gen = train._batches(x_all, y_all, batch, seed)

        def log(it, phase, cur_bits_spec):
            b_eval = eval_quant(params, bn_state, cfg, ev, cur_bits_spec)
            ba = float(
                np.mean(
                    [np.sum(np.clip(np.asarray(v), BITS_MIN, BITS_MAX)) for k, v in bits.items() if k.startswith("a")]
                )
            )
            bp = float(
                np.mean(
                    [np.sum(np.clip(np.asarray(v), BITS_MIN, BITS_MAX)) for k, v in bits.items() if k.startswith("w")]
                )
            )
            history.append(
                {"iter": it, "phase": phase, "b_act": ba, "b_par": bp, "ber": b_eval}
            )

        pm, pv, pt = opt_p.m, opt_p.v, opt_p.step
        # -------- Phase 1: full precision --------
        for it in range(iters_fp):
            xb, yb = next(gen)
            params, bn_state, pm, pv, pt, _ = step_fp(params, bn_state, pm, pv, pt, xb, yb)
            if it % eval_every == 0:
                log(it, 1, None)

        # -------- Phase 2: bit-width-aware --------
        bm, bv, bts = opt_b.m, opt_b.v, opt_b.step
        for it in range(iters_bits):
            xb, yb = next(gen)
            params, bits, bn_state, pm, pv, pt, bm, bv, bts, _ = step_bits(
                params, bits, bn_state, pm, pv, pt, bm, bv, bts, xb, yb
            )
            if it % eval_every == 0:
                log(iters_fp + it, 2, _quant_spec(bits))

        # -------- Phase 3: fine-tune with frozen integer widths --------
        frozen = frozen_bits(bits)
        frozen_spec = {k: (jnp.float32(v[0]), jnp.float32(v[1])) for k, v in frozen.items()}

        @jax.jit
        def step_ft(p, s, om, ov, ot, xb, yb):
            def lf(p_):
                pred, new_s = model.cnn_forward_batch(
                    p_, s, xb, cfg, train=True, quant=frozen_spec, use_pallas=False
                )
                return jnp.mean((pred - yb) ** 2), new_s

            (loss, new_s), g = jax.value_and_grad(lf, has_aux=True)(p)
            new_p, opt = train.adam_update(p, g, train.AdamState(om, ov, ot), lr=lr * 0.3)
            return new_p, new_s, opt.m, opt.v, opt.step, loss

        for it in range(iters_ft):
            xb, yb = next(gen)
            params, bn_state, pm, pv, pt, _ = step_ft(params, bn_state, pm, pv, pt, xb, yb)
            if it % eval_every == 0:
                # Bits are frozen: log the integer widths.
                sp = {k: (jnp.float32(v[0]), jnp.float32(v[1])) for k, v in frozen.items()}
                b_eval = eval_quant(params, bn_state, cfg, ev, sp)
                ba = float(np.mean([v[0] + v[1] for k, v in frozen.items() if k.startswith("a")]))
                bp = float(np.mean([v[0] + v[1] for k, v in frozen.items() if k.startswith("w")]))
                history.append(
                    {"iter": iters_fp + iters_bits + it, "phase": 3, "b_act": ba, "b_par": bp, "ber": b_eval}
                )
    finally:
        ref.fake_quant = orig_fq  # type: ignore[assignment]

    final_ber = eval_quant(
        params,
        bn_state,
        cfg,
        ev,
        {k: (jnp.float32(v[0]), jnp.float32(v[1])) for k, v in frozen.items()},
    )
    params["cfg"] = cfg_meta
    return QatResult(
        params=params, bn_state=bn_state, bits=frozen, history=history, ber=final_ber
    )


def eval_quant(
    params: Params,
    bn_state: Params,
    cfg: model.CnnConfig,
    data: channels.ChannelData,
    quant_spec,
    seq_sym: int = 256,
    max_windows: int = 64,
) -> float:
    p = {k: v for k, v in params.items() if k != "cfg"}
    x_all, y_all = channels.windows(data, seq_sym)
    x_all, y_all = x_all[:max_windows], y_all[:max_windows]

    @jax.jit
    def fwd(xb):
        return model.cnn_forward_batch(
            p, bn_state, xb, cfg, train=False, quant=quant_spec, use_pallas=False
        )[0]

    preds = np.asarray(fwd(jnp.asarray(x_all)))
    o = min(cfg.receptive_field_symbols(), preds.shape[1] // 4)
    return train.ber(preds[:, o:-o or None].reshape(-1), y_all[:, o:-o or None].reshape(-1))


def save_history(history: list[dict], path: str) -> None:
    with open(path, "w") as f:
        json.dump(history, f, indent=1)


def main() -> None:
    """Regenerate Figs. 5/6: bit-width and BER trajectories per QLF.

    Writes ``artifacts/qat_history_<channel>.json`` (one trajectory per
    QLF, the two figures' series) and ``qat_bits_<channel>.json`` (the
    learned formats from the smallest-QLF run — consumed by ``aot.py``
    for the quantized artifact).
    """
    import argparse
    import os

    ap = argparse.ArgumentParser(description=main.__doc__)
    ap.add_argument("--channel", default="imdd", choices=["imdd", "proakis"])
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--qlfs", default="0.5,0.005,0.0005")
    ap.add_argument("--iters-fp", type=int, default=2000)
    ap.add_argument("--iters-bits", type=int, default=2000)
    ap.add_argument("--iters-ft", type=int, default=1000)
    ap.add_argument("--n-sym", type=int, default=120_000)
    args = ap.parse_args()

    os.environ.setdefault("EQ_USE_PALLAS", "0")
    cfg = model.SELECTED
    data = channels.make_dataset(args.channel, args.n_sym, seed=0)
    ev = channels.make_dataset(args.channel, args.n_sym // 2, seed=1000)

    histories = {}
    final_bits = None
    fp_ref_ber = None
    for qlf in [float(q) for q in args.qlfs.split(",")]:
        print(f"[qat] QLF={qlf}")
        r = train_qat(
            cfg,
            data,
            qlf=qlf,
            iters_fp=args.iters_fp,
            iters_bits=args.iters_bits,
            iters_ft=args.iters_ft,
            eval_data=ev,
        )
        histories[str(qlf)] = r.history
        print(f"[qat] QLF={qlf}: final BER {r.ber:.3e}, bits {r.bits}")
        final_bits = r.bits  # smallest QLF runs last -> least aggressive
        fp_ref_ber = r.history[len(r.history) // 3]["ber"]

    os.makedirs(args.out_dir, exist_ok=True)
    with open(os.path.join(args.out_dir, f"qat_history_{args.channel}.json"), "w") as f:
        json.dump({"channel": args.channel, "fp_ref_ber": fp_ref_ber, "runs": histories}, f, indent=1)
    # Learned formats are written under a side name: the exported
    # quantized artifact keeps the paper's Sec. 4 operating point
    # (Q3.10 weights / Q4.6 activations) unless the user promotes the
    # learned file to qat_bits_<channel>.json.
    with open(os.path.join(args.out_dir, f"qat_bits_learned_{args.channel}.json"), "w") as f:
        json.dump({k: list(v) for k, v in final_bits.items()}, f, indent=1)
    print(f"[qat] wrote histories + bits to {args.out_dir}")


if __name__ == "__main__":
    main()
