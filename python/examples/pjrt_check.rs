// debug: compare PJRT output against the python test vector
use equalizer::runtime::{ArtifactRegistry, Engine};
use equalizer::util::json;
fn main() -> anyhow::Result<()> {
    let reg = ArtifactRegistry::discover("artifacts")?;
    let engine = Engine::cpu()?;
    let m = engine.load(reg.exact("cnn_imdd_w1024")?)?;
    let tv = json::parse_file("artifacts/testvec_cnn_imdd.json")?;
    let (x, _) = tv.req("x")?.as_tensor_f32()?;
    let (y_ref, _) = tv.req("y")?.as_tensor_f32()?;
    let y = m.run_f32(&x)?;
    let maxdiff = y.iter().zip(&y_ref).map(|(a,b)| (a-b).abs()).fold(0.0f32, f32::max);
    println!("len {} vs {}, maxdiff {}", y.len(), y_ref.len(), maxdiff);
    println!("first 8 rust:   {:?}", &y[..8]);
    println!("first 8 python: {:?}", &y_ref[..8]);
    Ok(())
}
