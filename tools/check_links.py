#!/usr/bin/env python3
"""Offline markdown link checker for this repo's docs.

Validates, for every markdown file passed on the command line:

  * relative file links resolve to an existing file or directory
    (fragment stripped first);
  * intra-file anchors (``[..](#section)``) and cross-file anchors
    (``[..](OTHER.md#section)``) match a heading slug in the target,
    using GitHub's slugging rules (lowercase, spaces -> dashes,
    punctuation dropped);
  * reference-style definitions (``[label]: target``) get the same
    treatment.

Skipped on purpose: absolute URLs (http/https/mailto) — this checker
must run offline — and repo-external relative paths like the
``../../actions/..`` CI badge, which are GitHub-site URLs, not files.

Exit status: number of broken links (0 = clean).
"""

import re
import sys
from pathlib import Path

# Inline links [text](target) — skipping images' leading ! is harmless
# (image paths deserve checking too).  Reference defs handled apart.
INLINE_LINK = re.compile(r"\[[^\]\[]*\]\(([^()\s]+)(?:\s+\"[^\"]*\")?\)")
REF_DEF = re.compile(r"^\s*\[[^\]]+\]:\s*(\S+)", re.MULTILINE)
HEADING = re.compile(r"^(#{1,6})\s+(.*)$", re.MULTILINE)
CODE_FENCE = re.compile(r"```.*?```", re.DOTALL)


def github_slug(heading: str) -> str:
    """Approximate GitHub's heading -> anchor slug."""
    # Drop inline code/markdown decoration, then slugify.
    text = re.sub(r"[`*_]", "", heading.strip())
    # Markdown links in headings keep only their text.
    text = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", text)
    text = text.lower()
    text = re.sub(r"[^\w\- ]", "", text, flags=re.UNICODE)
    return text.replace(" ", "-")


def heading_slugs(md_text: str) -> set:
    """All anchor slugs a markdown file exposes (with GitHub's -1, -2
    suffixing for duplicate headings)."""
    slugs: set = set()
    counts: dict = {}
    for match in HEADING.finditer(CODE_FENCE.sub("", md_text)):
        slug = github_slug(match.group(2))
        n = counts.get(slug, 0)
        counts[slug] = n + 1
        slugs.add(slug if n == 0 else f"{slug}-{n}")
    return slugs


def is_external(target: str) -> bool:
    return target.startswith(("http://", "https://", "mailto:", "ftp://"))


def check_file(md_path: Path, repo_root: Path) -> list:
    """Return a list of (target, reason) problems for one file."""
    text = md_path.read_text(encoding="utf-8")
    problems = []
    # Strip fenced code blocks for both scans: example links inside
    # ``` fences are illustrations, not links to validate.
    prose = CODE_FENCE.sub("", text)
    targets = [m.group(1) for m in INLINE_LINK.finditer(prose)]
    targets += [m.group(1) for m in REF_DEF.finditer(prose)]
    for target in targets:
        if is_external(target):
            continue
        if target.startswith("#"):
            if target[1:] not in heading_slugs(text):
                problems.append((target, "missing anchor"))
            continue
        path_part, _, fragment = target.partition("#")
        resolved = (md_path.parent / path_part).resolve()
        try:
            resolved.relative_to(repo_root)
        except ValueError:
            # Repo-external relative path (e.g. the ../../actions CI
            # badge): a GitHub-site URL, not a file — out of scope.
            continue
        if not resolved.exists():
            problems.append((target, "missing file"))
            continue
        if fragment and resolved.suffix == ".md":
            if fragment not in heading_slugs(resolved.read_text(encoding="utf-8")):
                problems.append((target, "missing anchor in target"))
    return problems


def main(argv: list) -> int:
    if len(argv) < 2:
        print("usage: check_links.py FILE.md [FILE.md ...]", file=sys.stderr)
        return 2
    repo_root = Path(__file__).resolve().parent.parent
    broken = 0
    for name in argv[1:]:
        md_path = Path(name)
        if not md_path.exists():
            print(f"{name}: file not found", file=sys.stderr)
            broken += 1
            continue
        for target, reason in check_file(md_path, repo_root):
            print(f"{name}: broken link {target!r} ({reason})", file=sys.stderr)
            broken += 1
    if broken == 0:
        print(f"check_links: {len(argv) - 1} file(s) clean")
    return min(broken, 125)


if __name__ == "__main__":
    sys.exit(main(sys.argv))
