//! Offline stand-in for the `anyhow` crate.
//!
//! This build environment resolves dependencies without a network, so
//! the real `anyhow` cannot be fetched.  This shim provides the subset
//! the workspace uses — a message-carrying [`Error`], the `Result`
//! alias, and the `anyhow!` / `bail!` / `ensure!` macros — with the
//! same surface syntax, so swapping in the real crate is a one-line
//! `Cargo.toml` change.

use std::fmt::{self, Display};

/// A string-backed error.  Like the real `anyhow::Error`, this type
/// deliberately does NOT implement `std::error::Error`, which is what
/// allows the blanket `From<E: std::error::Error>` conversion below to
/// exist without coherence conflicts.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: Display>(m: M) -> Self {
        Error { msg: m.to_string() }
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        // Flatten the source chain into the message, as `{:#}` would.
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            src = s.source();
        }
        Error { msg }
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)+) => { $crate::Error::msg(::std::format!($($arg)+)) };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => { return ::std::result::Result::Err($crate::anyhow!($($arg)+)) };
}

/// Return early with an error when a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", ::std::stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            $crate::bail!($($arg)+);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails(flag: bool) -> Result<u32> {
        ensure!(!flag, "flag was {flag}");
        Ok(7)
    }

    #[test]
    fn macros_roundtrip() {
        assert_eq!(fails(false).unwrap(), 7);
        let e = fails(true).unwrap_err();
        assert_eq!(e.to_string(), "flag was true");
        let e = anyhow!("x = {}", 3);
        assert_eq!(format!("{e:?}"), "x = 3");
    }

    #[test]
    fn ensure_without_message() {
        fn check(n: usize) -> Result<()> {
            ensure!(n > 2);
            Ok(())
        }
        assert!(check(3).is_ok());
        assert!(check(1).unwrap_err().to_string().contains("n > 2"));
    }

    #[test]
    fn std_errors_convert() {
        fn parse(s: &str) -> Result<i32> {
            Ok(s.parse::<i32>()?)
        }
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("nope").is_err());
    }
}
