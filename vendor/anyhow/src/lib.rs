//! Offline stand-in for the `anyhow` crate.
//!
//! This build environment resolves dependencies without a network, so
//! the real `anyhow` cannot be fetched.  This shim provides the subset
//! the workspace uses — a message-carrying [`Error`], the `Result`
//! alias, and the `anyhow!` / `bail!` / `ensure!` macros — with the
//! same surface syntax, so swapping in the real crate is a one-line
//! `Cargo.toml` change.

use std::fmt::{self, Display};

/// A string-backed error.  Like the real `anyhow::Error`, this type
/// deliberately does NOT implement `std::error::Error`, which is what
/// allows the blanket `From<E: std::error::Error>` conversion below to
/// exist without coherence conflicts.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: Display>(m: M) -> Self {
        Error { msg: m.to_string() }
    }

    /// Wrap the error with higher-level context, real-anyhow style:
    /// the context leads and the original message follows, matching
    /// what `{:#}` prints on a real `anyhow` chain.
    pub fn context<C: Display>(self, context: C) -> Self {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        // Flatten the source chain into the message, as `{:#}` would.
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            src = s.source();
        }
        Error { msg }
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Real-anyhow's context extension: attach a message to the error arm
/// of a `Result`.  Two impls (std errors and [`Error`] itself) cover
/// the workspace; they cannot overlap because [`Error`] deliberately
/// does not implement `std::error::Error`.
pub trait Context<T> {
    /// Wrap the error, if any, with `context`.
    fn context<C: Display>(self, context: C) -> Result<T>;
    /// Wrap the error, if any, with lazily-evaluated context.
    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for Result<T, E> {
    fn context<C: Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Result<T> {
    fn context<C: Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.context(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)+) => { $crate::Error::msg(::std::format!($($arg)+)) };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => { return ::std::result::Result::Err($crate::anyhow!($($arg)+)) };
}

/// Return early with an error when a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", ::std::stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            $crate::bail!($($arg)+);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails(flag: bool) -> Result<u32> {
        ensure!(!flag, "flag was {flag}");
        Ok(7)
    }

    #[test]
    fn macros_roundtrip() {
        assert_eq!(fails(false).unwrap(), 7);
        let e = fails(true).unwrap_err();
        assert_eq!(e.to_string(), "flag was true");
        let e = anyhow!("x = {}", 3);
        assert_eq!(format!("{e:?}"), "x = 3");
    }

    #[test]
    fn ensure_without_message() {
        fn check(n: usize) -> Result<()> {
            ensure!(n > 2);
            Ok(())
        }
        assert!(check(3).is_ok());
        assert!(check(1).unwrap_err().to_string().contains("n > 2"));
    }

    #[test]
    fn context_wraps_both_error_families() {
        let io: Result<(), std::io::Error> = Err(std::io::Error::other("boom"));
        let e = io.context("opening socket").unwrap_err();
        assert_eq!(e.to_string(), "opening socket: boom");
        let own: Result<()> = Err(anyhow!("inner"));
        let e = own.with_context(|| format!("pass {}", 2)).unwrap_err();
        assert_eq!(e.to_string(), "pass 2: inner");
    }

    #[test]
    fn std_errors_convert() {
        fn parse(s: &str) -> Result<i32> {
            Ok(s.parse::<i32>()?)
        }
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("nope").is_err());
    }
}
