//! Compile-time stub of the `xla` crate (xla_extension / PJRT bindings).
//!
//! The real crate links the `xla_extension` shared library, which is not
//! present in this offline image.  This stub mirrors the API surface the
//! `pjrt` feature of the `equalizer` crate uses, so `--features pjrt`
//! keeps type-checking; every runtime entry point returns an error.  To
//! run against real PJRT, point the `xla` path dependency (or a
//! `[patch]` entry) at the real crate — no source changes needed.

use std::fmt;

#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>() -> Result<T> {
    Err(Error(
        "PJRT runtime unavailable: this build links the in-tree `xla` stub; \
         vendor the real `xla` crate (see README \"Backends\") to execute HLO artifacts"
            .to_string(),
    ))
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        unavailable()
    }

    pub fn platform_name(&self) -> String {
        "xla-stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<std::path::Path>) -> Result<Self> {
        unavailable()
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

pub struct Literal;

impl Literal {
    pub fn vec1(_x: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable()
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable()
    }
}
